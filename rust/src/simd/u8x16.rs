//! 128-bit register model with NEON-named operations.
//!
//! [`U8x16`] models ARMv8 `uint8x16_t`, [`U16x8`] models `uint16x8_t`. The
//! free functions carry the exact NEON intrinsic names used by the paper's
//! implementation (faiss `simdlib_neon.h`) and follow the Arm ISA semantics
//! bit-for-bit — most importantly [`vqtbl1q_u8`], whose out-of-range-index
//! behaviour (yield 0 for index ≥ 16) differs from x86 `pshufb` (which keys
//! off bit 7 only).
//!
//! All operations are `#[inline(always)]` fixed-size array loops that LLVM
//! vectorizes on any target; they are the semantic reference the real-SIMD
//! backend ([`crate::simd::x86`]) is differential-tested against.

/// ARMv8 `uint8x16_t`: sixteen u8 lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(align(16))]
pub struct U8x16(pub [u8; 16]);

/// ARMv8 `uint16x8_t`: eight u16 lanes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(align(16))]
pub struct U16x8(pub [u16; 8]);

// ---------------------------------------------------------------- loads

/// `vld1q_u8`: load 16 bytes.
#[inline(always)]
pub fn vld1q_u8(p: &[u8]) -> U8x16 {
    let mut out = [0u8; 16];
    out.copy_from_slice(&p[..16]);
    U8x16(out)
}

/// `vdupq_n_u8`: broadcast a byte to all lanes.
#[inline(always)]
pub fn vdupq_n_u8(x: u8) -> U8x16 {
    U8x16([x; 16])
}

/// `vdupq_n_u16`: broadcast a u16 to all lanes.
#[inline(always)]
pub fn vdupq_n_u16(x: u16) -> U16x8 {
    U16x8([x; 8])
}

/// `vst1q_u8`: store 16 bytes.
#[inline(always)]
pub fn vst1q_u8(out: &mut [u8], v: U8x16) {
    out[..16].copy_from_slice(&v.0);
}

// ------------------------------------------------------------- the shuffle

/// `vqtbl1q_u8`: table lookup, the core instruction of the paper.
///
/// For each lane `i`: `out[i] = table[idx[i]]` if `idx[i] < 16` else `0`
/// (Arm ISA: out-of-range indices produce zero — unlike x86 `pshufb`).
#[inline(always)]
pub fn vqtbl1q_u8(table: U8x16, idx: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        let j = idx.0[i];
        out[i] = if j < 16 { table.0[j as usize] } else { 0 };
    }
    U8x16(out)
}

// ------------------------------------------------------------- bitwise

/// `vandq_u8`: lanewise AND.
#[inline(always)]
pub fn vandq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i] & b.0[i];
    }
    U8x16(out)
}

/// `vorrq_u8`: lanewise OR.
#[inline(always)]
pub fn vorrq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i] | b.0[i];
    }
    U8x16(out)
}

/// `veorq_u8`: lanewise XOR.
#[inline(always)]
pub fn veorq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i] ^ b.0[i];
    }
    U8x16(out)
}

/// `vshrq_n_u8::<N>`: lanewise logical shift right by constant.
#[inline(always)]
pub fn vshrq_n_u8<const N: i32>(a: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i] >> N;
    }
    U8x16(out)
}

/// `vshlq_n_u8::<N>`: lanewise logical shift left by constant.
#[inline(always)]
pub fn vshlq_n_u8<const N: i32>(a: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i] << N;
    }
    U8x16(out)
}

// ------------------------------------------------------------- arithmetic

/// `vaddq_u8`: lanewise wrapping add.
#[inline(always)]
pub fn vaddq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i].wrapping_add(b.0[i]);
    }
    U8x16(out)
}

/// `vqaddq_u8`: lanewise *saturating* add.
#[inline(always)]
pub fn vqaddq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i].saturating_add(b.0[i]);
    }
    U8x16(out)
}

/// `vminq_u8` / `vmaxq_u8`: lanewise min / max.
#[inline(always)]
pub fn vminq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i].min(b.0[i]);
    }
    U8x16(out)
}

#[inline(always)]
pub fn vmaxq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = a.0[i].max(b.0[i]);
    }
    U8x16(out)
}

// ------------------------------------------------------------- compare

/// `vceqq_u8`: lanewise equality → all-ones / all-zeros mask.
#[inline(always)]
pub fn vceqq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = if a.0[i] == b.0[i] { 0xFF } else { 0 };
    }
    U8x16(out)
}

/// `vcltq_u8`: lanewise unsigned `a < b` mask.
#[inline(always)]
pub fn vcltq_u8(a: U8x16, b: U8x16) -> U8x16 {
    let mut out = [0u8; 16];
    for i in 0..16 {
        out[i] = if a.0[i] < b.0[i] { 0xFF } else { 0 };
    }
    U8x16(out)
}

/// `vcltq_u16`: lanewise unsigned `a < b` mask on u16 lanes.
#[inline(always)]
pub fn vcltq_u16(a: U16x8, b: U16x8) -> U16x8 {
    let mut out = [0u16; 8];
    for i in 0..8 {
        out[i] = if a.0[i] < b.0[i] { 0xFFFF } else { 0 };
    }
    U16x8(out)
}

// ------------------------------------------------------------- widening

/// `vget_low_u8` + `vmovl_u8`: widen the low 8 bytes to u16 lanes.
#[inline(always)]
pub fn vmovl_low_u8(a: U8x16) -> U16x8 {
    let mut out = [0u16; 8];
    for i in 0..8 {
        out[i] = a.0[i] as u16;
    }
    U16x8(out)
}

/// `vget_high_u8` + `vmovl_u8`: widen the high 8 bytes to u16 lanes.
#[inline(always)]
pub fn vmovl_high_u8(a: U8x16) -> U16x8 {
    let mut out = [0u16; 8];
    for i in 0..8 {
        out[i] = a.0[i + 8] as u16;
    }
    U16x8(out)
}

// ------------------------------------------------------------- u16 math

/// `vaddq_u16`: lanewise wrapping add.
#[inline(always)]
pub fn vaddq_u16(a: U16x8, b: U16x8) -> U16x8 {
    let mut out = [0u16; 8];
    for i in 0..8 {
        out[i] = a.0[i].wrapping_add(b.0[i]);
    }
    U16x8(out)
}

/// `vqaddq_u16`: lanewise *saturating* add — the accumulator instruction of
/// the fastscan kernel (distances must clamp, not wrap).
#[inline(always)]
pub fn vqaddq_u16(a: U16x8, b: U16x8) -> U16x8 {
    let mut out = [0u16; 8];
    for i in 0..8 {
        out[i] = a.0[i].saturating_add(b.0[i]);
    }
    U16x8(out)
}

/// `vminq_u16`: lanewise min.
#[inline(always)]
pub fn vminq_u16(a: U16x8, b: U16x8) -> U16x8 {
    let mut out = [0u16; 8];
    for i in 0..8 {
        out[i] = a.0[i].min(b.0[i]);
    }
    U16x8(out)
}

/// `vminvq_u16`: horizontal minimum across lanes.
#[inline(always)]
pub fn vminvq_u16(a: U16x8) -> u16 {
    let mut m = a.0[0];
    for i in 1..8 {
        m = m.min(a.0[i]);
    }
    m
}

/// `vst1q_u16`: store 8 u16 lanes.
#[inline(always)]
pub fn vst1q_u16(out: &mut [u16], v: U16x8) {
    out[..8].copy_from_slice(&v.0);
}

// --------------------------------------------------- movemask emulation

/// Emulation of x86 `_mm_movemask_epi8` on a 128-bit lane — one of the
/// "auxiliary instructions only present in AVX2 but not in ARM" the paper
/// implements (§3). Collects the top bit of every byte lane into a u16.
///
/// NEON realization (as in faiss `simdlib_neon.h`): shift each byte right
/// by 7, multiply-accumulate against a power-of-two weight vector via
/// narrowing pairwise adds. Modeled here lane-by-lane.
#[inline(always)]
pub fn vmovmaskq_u8(a: U8x16) -> u16 {
    let mut m = 0u16;
    for i in 0..16 {
        m |= (((a.0[i] >> 7) & 1) as u16) << i;
    }
    m
}

/// Same idea on u16 lanes: one mask bit per u16 lane (8 bits).
#[inline(always)]
pub fn vmovmaskq_u16(a: U16x8) -> u8 {
    let mut m = 0u8;
    for i in 0..8 {
        m |= (((a.0[i] >> 15) & 1) as u8) << i;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng) -> U8x16 {
        let mut v = [0u8; 16];
        for b in &mut v {
            *b = (rng.next_u32() & 0xFF) as u8;
        }
        U8x16(v)
    }

    #[test]
    fn tbl_in_range() {
        let table = U8x16([10, 11, 12, 13, 14, 15, 16, 17, 18, 19, 20, 21, 22, 23, 24, 25]);
        let idx = U8x16([0, 15, 1, 14, 2, 13, 3, 12, 4, 11, 5, 10, 6, 9, 7, 8]);
        let out = vqtbl1q_u8(table, idx);
        for i in 0..16 {
            assert_eq!(out.0[i], table.0[idx.0[i] as usize]);
        }
    }

    #[test]
    fn tbl_out_of_range_yields_zero() {
        // NEON semantics: index >= 16 -> 0 (x86 pshufb would wrap low nibble
        // unless bit 7 set — this difference is why the paper needed care).
        let table = vdupq_n_u8(0xAB);
        let idx = U8x16([16, 17, 100, 255, 0, 1, 2, 3, 31, 64, 128, 200, 15, 14, 13, 12]);
        let out = vqtbl1q_u8(table, idx);
        assert_eq!(out.0[..4], [0, 0, 0, 0]);
        assert_eq!(out.0[4..8], [0xAB; 4]);
        assert_eq!(out.0[8..12], [0, 0, 0, 0]);
        assert_eq!(out.0[12..16], [0xAB; 4]);
    }

    #[test]
    fn nibble_masking_pipeline() {
        // The fastscan idiom: extract lo/hi nibbles then lookup.
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let packed = rand_vec(&mut rng);
            let mask = vdupq_n_u8(0x0F);
            let lo = vandq_u8(packed, mask);
            let hi = vandq_u8(vshrq_n_u8::<4>(packed), mask);
            for i in 0..16 {
                assert_eq!(lo.0[i], packed.0[i] & 0xF);
                assert_eq!(hi.0[i], packed.0[i] >> 4);
                assert!(lo.0[i] < 16 && hi.0[i] < 16);
            }
        }
    }

    #[test]
    fn saturating_adds() {
        let a = vdupq_n_u8(200);
        let b = vdupq_n_u8(100);
        assert_eq!(vqaddq_u8(a, b).0, [255u8; 16]);
        assert_eq!(vaddq_u8(a, b).0, [44u8; 16]); // wrapping
        let a16 = vdupq_n_u16(65_000);
        let b16 = vdupq_n_u16(1_000);
        assert_eq!(vqaddq_u16(a16, b16).0, [65_535u16; 8]);
    }

    #[test]
    fn widening_splits() {
        let a = U8x16([0, 1, 2, 3, 4, 5, 6, 7, 250, 251, 252, 253, 254, 255, 9, 8]);
        assert_eq!(vmovl_low_u8(a).0, [0, 1, 2, 3, 4, 5, 6, 7]);
        assert_eq!(vmovl_high_u8(a).0, [250, 251, 252, 253, 254, 255, 9, 8]);
    }

    #[test]
    fn movemask_bits() {
        let mut v = [0u8; 16];
        v[0] = 0x80;
        v[3] = 0xFF;
        v[15] = 0x90;
        assert_eq!(vmovmaskq_u8(U8x16(v)), (1 << 0) | (1 << 3) | (1 << 15));
        assert_eq!(vmovmaskq_u8(vdupq_n_u8(0)), 0);
        assert_eq!(vmovmaskq_u8(vdupq_n_u8(0xFF)), 0xFFFF);
    }

    #[test]
    fn movemask_u16_bits() {
        let a = U16x8([0xFFFF, 0, 0x8000, 0x7FFF, 0, 0xFFFF, 0, 0]);
        assert_eq!(vmovmaskq_u16(a), 0b0010_0101);
    }

    #[test]
    fn compare_masks() {
        let a = U8x16([1, 5, 200, 0, 7, 7, 255, 0, 1, 2, 3, 4, 5, 6, 7, 8]);
        let b = vdupq_n_u8(7);
        let lt = vcltq_u8(a, b);
        for i in 0..16 {
            assert_eq!(lt.0[i] == 0xFF, a.0[i] < 7);
        }
        let eq = vceqq_u8(a, b);
        for i in 0..16 {
            assert_eq!(eq.0[i] == 0xFF, a.0[i] == 7);
        }
    }

    #[test]
    fn min_max_horizontal() {
        let a = U16x8([9, 3, 7, 5, 11, 3, 200, 65535]);
        assert_eq!(vminvq_u16(a), 3);
        let b = vdupq_n_u16(6);
        assert_eq!(vminq_u16(a, b).0, [6, 3, 6, 5, 6, 3, 6, 6]);
    }

    #[test]
    fn bitwise_ops_random() {
        let mut rng = Rng::new(2);
        for _ in 0..50 {
            let a = rand_vec(&mut rng);
            let b = rand_vec(&mut rng);
            for i in 0..16 {
                assert_eq!(vandq_u8(a, b).0[i], a.0[i] & b.0[i]);
                assert_eq!(vorrq_u8(a, b).0[i], a.0[i] | b.0[i]);
                assert_eq!(veorq_u8(a, b).0[i], a.0[i] ^ b.0[i]);
                assert_eq!(vshlq_n_u8::<4>(a).0[i], a.0[i] << 4);
            }
        }
    }

    #[test]
    fn load_store_roundtrip() {
        let bytes: Vec<u8> = (0..16).collect();
        let v = vld1q_u8(&bytes);
        let mut out = [0u8; 16];
        vst1q_u8(&mut out, v);
        assert_eq!(out.to_vec(), bytes);
    }
}
