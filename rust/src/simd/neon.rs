//! Real-SIMD backend: the dual-lane 256-bit register model implemented with
//! genuine ARM NEON intrinsics (`core::arch::aarch64`).
//!
//! This is the paper's actual target: two 128-bit Q-registers
//! (`uint8x16x2_t`) bundled into one virtual 256-bit register, the AVX2
//! `_mm256_shuffle_epi8` table lookup emulated as **two `vqtbl1q_u8`
//! shuffles** (paper §3, Fig. 1c), and the AVX2-only `movemask`
//! re-created from NEON primitives via the narrowing-shift
//! (`vshrn`) + scalar-extract idiom.
//!
//! The portable model ([`crate::simd::u8x16`]/[`crate::simd::simd256`])
//! is the semantic reference; this module is differential-tested against
//! it exactly as [`crate::simd::x86`] is on x86_64 hosts. `vqtbl1q_u8`
//! zeroes out-of-range indices (unlike `pshufb`, which keys on bit 7);
//! every fastscan call site masks indices to `0..16`, where the portable
//! model, SSSE3 and NEON agree bit-for-bit.
//!
//! All functions are `unsafe` because of `#[target_feature]`; callers gate
//! on [`crate::simd::best_backend`]. NEON is mandatory in AArch64, so on
//! any aarch64 host the gate passes.

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::aarch64::*;

/// Emulated `_mm_movemask_epi8` on one 128-bit lane — the paper's §3
/// "auxiliary instruction only present in AVX2": collect the top bit of
/// each byte lane into a `u16`.
///
/// Idiom: arithmetic-shift each byte to an all-ones/all-zeros mask, fold
/// each byte into a nibble with the narrowing shift `vshrn_n_u16`, extract
/// the resulting 64-bit "nibble mask" as a scalar, then compress 4 bits →
/// 1 bit per lane with shift-or steps.
#[inline]
#[target_feature(enable = "neon")]
pub unsafe fn neon_movemask_u8(v: uint8x16_t) -> u16 {
    // 0xFF for every byte with the top bit set, 0x00 otherwise.
    let m = vreinterpretq_u8_s8(vshrq_n_s8::<7>(vreinterpretq_s8_u8(v)));
    // Narrowing shift: each u16 pair (b0, b1) becomes the byte
    // (b1 & 0xF0) | (b0 >> 4) — i.e. one nibble of flag per input byte.
    let nib = vshrn_n_u16::<4>(vreinterpretq_u16_u8(m));
    let x = vget_lane_u64::<0>(vreinterpret_u64_u8(nib));
    // Compress the 16 flag nibbles (bit 4i) down to 16 contiguous bits.
    let x = x & 0x1111_1111_1111_1111;
    let x = (x | (x >> 3)) & 0x0303_0303_0303_0303;
    let x = (x | (x >> 6)) & 0x000F_000F_000F_000F;
    let x = (x | (x >> 12)) & 0x0000_00FF_0000_00FF;
    let x = (x | (x >> 24)) & 0xFFFF;
    x as u16
}

/// Dual-lane 256-bit register backed by two `uint8x16_t` Q-registers —
/// the paper's `uint8x16x2_t`.
#[derive(Clone, Copy)]
pub struct NeonSimd256u8 {
    pub lo: uint8x16_t,
    pub hi: uint8x16_t,
}

/// Dual-lane u16 accumulator backed by two `uint16x8_t` (8 lanes each,
/// bundled twice → 16 lanes, matching [`crate::simd::Simd256u16`]).
#[derive(Clone, Copy)]
pub struct NeonSimd256u16 {
    pub lo: uint16x8_t,
    pub hi: uint16x8_t,
}

impl NeonSimd256u8 {
    /// Load 32 bytes (unaligned).
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn load(p: *const u8) -> Self {
        Self { lo: vld1q_u8(p), hi: vld1q_u8(p.add(16)) }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn splat(x: u8) -> Self {
        let v = vdupq_n_u8(x);
        Self { lo: v, hi: v }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn store(self, out: *mut u8) {
        vst1q_u8(out, self.lo);
        vst1q_u8(out.add(16), self.hi);
    }

    /// The paper's core operation (Fig. 1c): the 256-bit
    /// `_mm256_shuffle_epi8` as two `vqtbl1q_u8` — lane `lo` against table
    /// T¹, lane `hi` against T². Indices must already be masked to `0..16`.
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn shuffle_dual(tables: Self, idx: Self) -> Self {
        Self { lo: vqtbl1q_u8(tables.lo, idx.lo), hi: vqtbl1q_u8(tables.hi, idx.hi) }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn and(self, other: Self) -> Self {
        Self { lo: vandq_u8(self.lo, other.lo), hi: vandq_u8(self.hi, other.hi) }
    }

    /// Logical shift right by 4 within each byte (nibble extraction —
    /// native on NEON, no u16 detour like SSE needs).
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn shr4(self) -> Self {
        Self { lo: vshrq_n_u8::<4>(self.lo), hi: vshrq_n_u8::<4>(self.hi) }
    }

    /// Emulated `_mm256_movemask_epi8` on both lanes → 32-bit mask.
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn movemask(self) -> u32 {
        (neon_movemask_u8(self.lo) as u32) | ((neon_movemask_u8(self.hi) as u32) << 16)
    }

    /// Zero-extend the 32 u8 lanes to two 16-lane u16 registers
    /// (`vmovl_u8` on the low half, `vmovl_high_u8` on the high half).
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn widen(self) -> (NeonSimd256u16, NeonSimd256u16) {
        (
            NeonSimd256u16 { lo: vmovl_u8(vget_low_u8(self.lo)), hi: vmovl_high_u8(self.lo) },
            NeonSimd256u16 { lo: vmovl_u8(vget_low_u8(self.hi)), hi: vmovl_high_u8(self.hi) },
        )
    }
}

impl NeonSimd256u16 {
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn zero() -> Self {
        let z = vdupq_n_u16(0);
        Self { lo: z, hi: z }
    }

    /// Saturating u16 accumulate (`vqaddq_u16` — distances clamp, not wrap).
    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn sat_add(self, other: Self) -> Self {
        Self { lo: vqaddq_u16(self.lo, other.lo), hi: vqaddq_u16(self.hi, other.hi) }
    }

    #[inline]
    #[target_feature(enable = "neon")]
    pub unsafe fn store(self, out: *mut u16) {
        vst1q_u16(out, self.lo);
        vst1q_u16(out.add(8), self.hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::{available_backends, Backend, Simd256u8};
    use crate::util::rng::Rng;

    fn have_neon() -> bool {
        available_backends().contains(&Backend::Neon)
    }

    /// Differential test: the NEON backend must agree with the portable
    /// NEON-semantics model on the masked-index domain used by fastscan.
    #[test]
    fn shuffle_dual_matches_portable() {
        if !have_neon() {
            eprintln!("skipping: no neon");
            return;
        }
        let mut rng = Rng::new(87);
        for _ in 0..500 {
            let mut tables = [0u8; 32];
            let mut idx = [0u8; 32];
            for b in &mut tables {
                *b = (rng.next_u32() & 0xFF) as u8;
            }
            for b in &mut idx {
                *b = (rng.next_u32() % 16) as u8; // masked domain
            }
            // portable
            let pt = Simd256u8::load(&tables);
            let pi = Simd256u8::load(&idx);
            let mut expect = [0u8; 32];
            Simd256u8::shuffle_dual(pt, pi).store(&mut expect);
            // neon
            let mut got = [0u8; 32];
            unsafe {
                let nt = NeonSimd256u8::load(tables.as_ptr());
                let ni = NeonSimd256u8::load(idx.as_ptr());
                NeonSimd256u8::shuffle_dual(nt, ni).store(got.as_mut_ptr());
            }
            assert_eq!(got, expect);
        }
    }

    /// `vqtbl1q_u8` out-of-range behaviour must match the portable model
    /// (zero, not pshufb wraparound) — this is the ISA detail the portable
    /// model encodes and the x86 backend has to avoid by masking.
    #[test]
    fn tbl_out_of_range_yields_zero() {
        if !have_neon() {
            eprintln!("skipping: no neon");
            return;
        }
        let tables = [0xABu8; 32];
        let idx: [u8; 32] = [
            16, 17, 100, 255, 0, 1, 2, 3, 31, 64, 128, 200, 15, 14, 13, 12, 16, 17, 100, 255, 0,
            1, 2, 3, 31, 64, 128, 200, 15, 14, 13, 12,
        ];
        let pt = Simd256u8::load(&tables);
        let pi = Simd256u8::load(&idx);
        let mut expect = [0u8; 32];
        Simd256u8::shuffle_dual(pt, pi).store(&mut expect);
        let mut got = [0u8; 32];
        unsafe {
            let nt = NeonSimd256u8::load(tables.as_ptr());
            let ni = NeonSimd256u8::load(idx.as_ptr());
            NeonSimd256u8::shuffle_dual(nt, ni).store(got.as_mut_ptr());
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn nibble_and_widen_match_portable() {
        if !have_neon() {
            eprintln!("skipping: no neon");
            return;
        }
        let mut rng = Rng::new(88);
        for _ in 0..200 {
            let mut packed = [0u8; 32];
            for b in &mut packed {
                *b = (rng.next_u32() & 0xFF) as u8;
            }
            // portable reference
            let c = Simd256u8::load(&packed);
            let mask = Simd256u8::splat(0x0F);
            let mut lo_e = [0u8; 32];
            let mut hi_e = [0u8; 32];
            c.and(mask).store(&mut lo_e);
            c.shr4().store(&mut hi_e);
            let (w0, w1) = c.widen();
            let mut w0_e = [0u16; 16];
            let mut w1_e = [0u16; 16];
            w0.store(&mut w0_e);
            w1.store(&mut w1_e);
            // neon
            unsafe {
                let nc = NeonSimd256u8::load(packed.as_ptr());
                let nm = NeonSimd256u8::splat(0x0F);
                let mut lo_g = [0u8; 32];
                let mut hi_g = [0u8; 32];
                nc.and(nm).store(lo_g.as_mut_ptr());
                nc.shr4().store(hi_g.as_mut_ptr());
                assert_eq!(lo_g, lo_e);
                assert_eq!(hi_g, hi_e);
                let (n0, n1) = nc.widen();
                let mut w0_g = [0u16; 16];
                let mut w1_g = [0u16; 16];
                n0.store(w0_g.as_mut_ptr());
                n1.store(w1_g.as_mut_ptr());
                assert_eq!(w0_g, w0_e);
                assert_eq!(w1_g, w1_e);
            }
        }
    }

    #[test]
    fn sat_add_matches_portable() {
        if !have_neon() {
            eprintln!("skipping: no neon");
            return;
        }
        unsafe {
            let a = NeonSimd256u16 { lo: vdupq_n_u16(64_000), hi: vdupq_n_u16(1_000) };
            let b = NeonSimd256u16 { lo: vdupq_n_u16(5_000), hi: vdupq_n_u16(2_000) };
            let mut out = [0u16; 16];
            a.sat_add(b).store(out.as_mut_ptr());
            assert_eq!(out[..8], [u16::MAX; 8]); // 64000 + 5000 saturates
            assert_eq!(out[8..], [3_000u16; 8]);
        }
    }

    #[test]
    fn movemask_matches_portable() {
        if !have_neon() {
            eprintln!("skipping: no neon");
            return;
        }
        let mut rng = Rng::new(89);
        for _ in 0..200 {
            let mut b = [0u8; 32];
            for x in &mut b {
                *x = (rng.next_u32() & 0xFF) as u8;
            }
            let expect = Simd256u8::load(&b).movemask();
            let got = unsafe { NeonSimd256u8::load(b.as_ptr()).movemask() };
            assert_eq!(got, expect);
        }
    }
}
