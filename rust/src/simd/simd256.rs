//! Virtual 256-bit registers from two 128-bit lanes — the paper's §3.
//!
//! [`Simd256u8`] models `uint8x16x2_t`: *"we concatenate two 128-bit SIMD
//! registers and use them as if it is a single 256-bit register"*. The key
//! operation is [`Simd256u8::shuffle_dual`], which reproduces AVX2
//! `_mm256_shuffle_epi8` as two `vqtbl1q_u8` calls — lane 0 against table
//! `T¹`, lane 1 against table `T²` (paper Fig. 1c).
//!
//! [`Simd256u16`] is the matching 16-lane u16 accumulator pair
//! (`uint16x8x2_t` twice), with the saturating add used by the fastscan
//! distance accumulation, and [`Simd256u8::movemask`] reproduces
//! `_mm256_movemask_epi8`, the auxiliary AVX2 instruction the paper had to
//! re-create on NEON.

use super::u8x16::*;

/// `uint8x16x2_t`: two 128-bit lanes handled as one 256-bit register.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Simd256u8 {
    pub lo: U8x16,
    pub hi: U8x16,
}

impl Simd256u8 {
    /// Load 32 bytes.
    #[inline(always)]
    pub fn load(p: &[u8]) -> Self {
        Self { lo: vld1q_u8(&p[..16]), hi: vld1q_u8(&p[16..32]) }
    }

    /// Broadcast one byte to all 32 lanes.
    #[inline(always)]
    pub fn splat(x: u8) -> Self {
        Self { lo: vdupq_n_u8(x), hi: vdupq_n_u8(x) }
    }

    /// Store 32 bytes.
    #[inline(always)]
    pub fn store(self, out: &mut [u8]) {
        vst1q_u8(&mut out[..16], self.lo);
        vst1q_u8(&mut out[16..32], self.hi);
    }

    /// The paper's core operation (Fig. 1c): emulate the 256-bit
    /// `_mm256_shuffle_epi8` with two 128-bit `vqtbl1q_u8` shuffles.
    ///
    /// * lane `lo` (indices `k₁ … k₁₆`) is looked up in `tables.lo` (T¹)
    /// * lane `hi` (indices `k₁₇ … k₃₂`) is looked up in `tables.hi` (T²)
    ///
    /// Caller guarantees indices are already masked to `0..16`; NEON (unlike
    /// pshufb) yields 0 for out-of-range indices, which [`vqtbl1q_u8`]
    /// models faithfully.
    #[inline(always)]
    pub fn shuffle_dual(tables: Simd256u8, idx: Simd256u8) -> Simd256u8 {
        Simd256u8 {
            lo: vqtbl1q_u8(tables.lo, idx.lo), // first 128 bits with T¹
            hi: vqtbl1q_u8(tables.hi, idx.hi), // last 128 bits with T²
        }
    }

    /// Lanewise AND.
    #[inline(always)]
    pub fn and(self, other: Simd256u8) -> Simd256u8 {
        Simd256u8 { lo: vandq_u8(self.lo, other.lo), hi: vandq_u8(self.hi, other.hi) }
    }

    /// Lanewise logical shift right by 4 (nibble extraction).
    #[inline(always)]
    pub fn shr4(self) -> Simd256u8 {
        Simd256u8 { lo: vshrq_n_u8::<4>(self.lo), hi: vshrq_n_u8::<4>(self.hi) }
    }

    /// Nibble-split of the **lo** 128-bit lane across both lanes:
    /// `{ lo: self.lo & 0xF, hi: self.lo >> 4 }`. This is the 8-bit
    /// fastscan index register ([`crate::pq::fastscan::LaneWiring::SplitNibble`]):
    /// each code byte's low nibble addresses the lo-half table `T_lo` and
    /// its high nibble the hi-half table `T_hi` through one dual shuffle.
    #[inline(always)]
    pub fn nibble_split_lo(self) -> Simd256u8 {
        Simd256u8 {
            lo: vandq_u8(self.lo, vdupq_n_u8(0x0F)),
            hi: vshrq_n_u8::<4>(self.lo),
        }
    }

    /// Nibble-split of the **hi** 128-bit lane (vectors 16..32), same
    /// arrangement as [`Simd256u8::nibble_split_lo`].
    #[inline(always)]
    pub fn nibble_split_hi(self) -> Simd256u8 {
        Simd256u8 {
            lo: vandq_u8(self.hi, vdupq_n_u8(0x0F)),
            hi: vshrq_n_u8::<4>(self.hi),
        }
    }

    /// Lanewise saturating add.
    #[inline(always)]
    pub fn sat_add(self, other: Simd256u8) -> Simd256u8 {
        Simd256u8 { lo: vqaddq_u8(self.lo, other.lo), hi: vqaddq_u8(self.hi, other.hi) }
    }

    /// Lanewise unsigned `self < other` mask.
    #[inline(always)]
    pub fn lt(self, other: Simd256u8) -> Simd256u8 {
        Simd256u8 { lo: vcltq_u8(self.lo, other.lo), hi: vcltq_u8(self.hi, other.hi) }
    }

    /// Emulated `_mm256_movemask_epi8`: top bit of each of the 32 byte
    /// lanes, collected into a `u32` (lane `lo` → bits 0–15, `hi` → 16–31).
    #[inline(always)]
    pub fn movemask(self) -> u32 {
        (vmovmaskq_u8(self.lo) as u32) | ((vmovmaskq_u8(self.hi) as u32) << 16)
    }

    /// Widen the 32 u8 lanes into a pair of 16-lane u16 registers:
    /// `(lanes 0..16, lanes 16..32)`.
    #[inline(always)]
    pub fn widen(self) -> (Simd256u16, Simd256u16) {
        (
            Simd256u16 { lo: vmovl_low_u8(self.lo), hi: vmovl_high_u8(self.lo) },
            Simd256u16 { lo: vmovl_low_u8(self.hi), hi: vmovl_high_u8(self.hi) },
        )
    }
}

/// Two `uint16x8_t` lanes as one 256-bit register of 16 u16 accumulators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Simd256u16 {
    pub lo: U16x8,
    pub hi: U16x8,
}

impl Simd256u16 {
    #[inline(always)]
    pub fn zero() -> Self {
        Self::default()
    }

    #[inline(always)]
    pub fn splat(x: u16) -> Self {
        Self { lo: vdupq_n_u16(x), hi: vdupq_n_u16(x) }
    }

    /// Saturating accumulate — the fastscan distance accumulator.
    #[inline(always)]
    pub fn sat_add(self, other: Simd256u16) -> Simd256u16 {
        Simd256u16 { lo: vqaddq_u16(self.lo, other.lo), hi: vqaddq_u16(self.hi, other.hi) }
    }

    /// Lanewise min (used for pruning bound maintenance).
    #[inline(always)]
    pub fn min(self, other: Simd256u16) -> Simd256u16 {
        Simd256u16 { lo: vminq_u16(self.lo, other.lo), hi: vminq_u16(self.hi, other.hi) }
    }

    /// Horizontal min across all 16 lanes.
    #[inline(always)]
    pub fn hmin(self) -> u16 {
        vminvq_u16(self.lo).min(vminvq_u16(self.hi))
    }

    /// Lanewise `self < other` mask.
    #[inline(always)]
    pub fn lt(self, other: Simd256u16) -> Simd256u16 {
        Simd256u16 { lo: vcltq_u16(self.lo, other.lo), hi: vcltq_u16(self.hi, other.hi) }
    }

    /// One mask bit per u16 lane (16 bits total).
    #[inline(always)]
    pub fn movemask(self) -> u16 {
        (vmovmaskq_u16(self.lo) as u16) | ((vmovmaskq_u16(self.hi) as u16) << 8)
    }

    /// Store all 16 lanes.
    #[inline(always)]
    pub fn store(self, out: &mut [u16]) {
        vst1q_u16(&mut out[..8], self.lo);
        vst1q_u16(&mut out[8..16], self.hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_bytes(rng: &mut Rng, n: usize) -> Vec<u8> {
        (0..n).map(|_| (rng.next_u32() & 0xFF) as u8).collect()
    }

    #[test]
    fn dual_shuffle_matches_scalar_model() {
        // Scalar model of _mm256_shuffle_epi8 with per-lane tables: this is
        // exactly the paper's Fig. 1c semantics.
        let mut rng = Rng::new(42);
        for _ in 0..200 {
            let t1 = rand_bytes(&mut rng, 16);
            let t2 = rand_bytes(&mut rng, 16);
            let idx: Vec<u8> = (0..32).map(|_| (rng.next_u32() % 16) as u8).collect();
            let tables =
                Simd256u8 { lo: vld1q_u8(&t1), hi: vld1q_u8(&t2) };
            let got = Simd256u8::shuffle_dual(tables, Simd256u8::load(&idx));
            let mut out = [0u8; 32];
            got.store(&mut out);
            for i in 0..16 {
                assert_eq!(out[i], t1[idx[i] as usize], "lane lo {i}");
                assert_eq!(out[16 + i], t2[idx[16 + i] as usize], "lane hi {i}");
            }
        }
    }

    #[test]
    fn nibble_extract_256() {
        let mut rng = Rng::new(3);
        let packed = rand_bytes(&mut rng, 32);
        let c = Simd256u8::load(&packed);
        let mask = Simd256u8::splat(0x0F);
        let lo = c.and(mask);
        let hi = c.shr4().and(mask);
        let mut lo_b = [0u8; 32];
        let mut hi_b = [0u8; 32];
        lo.store(&mut lo_b);
        hi.store(&mut hi_b);
        for i in 0..32 {
            assert_eq!(lo_b[i], packed[i] & 0xF);
            assert_eq!(hi_b[i], packed[i] >> 4);
        }
    }

    #[test]
    fn nibble_split_lanes() {
        let mut rng = Rng::new(9);
        let bytes = rand_bytes(&mut rng, 32);
        let c = Simd256u8::load(&bytes);
        let mut a = [0u8; 32];
        let mut b = [0u8; 32];
        c.nibble_split_lo().store(&mut a);
        c.nibble_split_hi().store(&mut b);
        for i in 0..16 {
            assert_eq!(a[i], bytes[i] & 0xF, "split_lo lane-lo {i}");
            assert_eq!(a[16 + i], bytes[i] >> 4, "split_lo lane-hi {i}");
            assert_eq!(b[i], bytes[16 + i] & 0xF, "split_hi lane-lo {i}");
            assert_eq!(b[16 + i], bytes[16 + i] >> 4, "split_hi lane-hi {i}");
        }
    }

    #[test]
    fn movemask_256() {
        let mut b = [0u8; 32];
        b[0] = 0x80;
        b[15] = 0xFF;
        b[16] = 0x80;
        b[31] = 0xC0;
        let m = Simd256u8::load(&b).movemask();
        assert_eq!(m, (1 << 0) | (1 << 15) | (1 << 16) | (1u32 << 31));
    }

    #[test]
    fn widen_is_zero_extension() {
        let mut rng = Rng::new(4);
        let b = rand_bytes(&mut rng, 32);
        let (w0, w1) = Simd256u8::load(&b).widen();
        let mut o0 = [0u16; 16];
        let mut o1 = [0u16; 16];
        w0.store(&mut o0);
        w1.store(&mut o1);
        for i in 0..16 {
            assert_eq!(o0[i], b[i] as u16);
            assert_eq!(o1[i], b[16 + i] as u16);
        }
    }

    #[test]
    fn u16_sat_accumulate() {
        let mut acc = Simd256u16::splat(65_000);
        acc = acc.sat_add(Simd256u16::splat(1_000));
        let mut out = [0u16; 16];
        acc.store(&mut out);
        assert_eq!(out, [u16::MAX; 16]);
    }

    #[test]
    fn u16_hmin_and_mask() {
        let mut a = Simd256u16::splat(100);
        a.lo.0[3] = 5;
        a.hi.0[7] = 2;
        assert_eq!(a.hmin(), 2);
        let thresh = Simd256u16::splat(6);
        let m = a.lt(thresh).movemask();
        // lane 3 (lo) and lane 15 (hi[7]) are below 6
        assert_eq!(m, (1 << 3) | (1 << 15));
    }

    #[test]
    fn sat_add_u8_clamps() {
        let a = Simd256u8::splat(250);
        let b = Simd256u8::splat(10);
        let mut out = [0u8; 32];
        a.sat_add(b).store(&mut out);
        assert_eq!(out, [255u8; 32]);
    }
}
