//! Real-SIMD backend: the dual-lane 256-bit register model implemented with
//! SSE/SSSE3 intrinsics (x86_64 hosts).
//!
//! This mirrors the relationship in the paper's code between
//! `simdlib_neon.h` (two `uint8x16_t`) and `simdlib_avx2.h` (one
//! `__m256i`): the *same interface*, backed by whatever 128-bit shuffle
//! hardware the host provides. Here each lane is a `__m128i` and the table
//! lookup is `pshufb`.
//!
//! `pshufb` and `vqtbl1q_u8` differ on out-of-range indices (`pshufb` keys
//! on bit 7, TBL zeroes for any index ≥ 16). Every fastscan call site masks
//! indices to `0..16` first, where the two are identical; the differential
//! tests below check exactly that contract.
//!
//! All functions are `unsafe` because of `#[target_feature]`; callers gate
//! on [`crate::simd::best_backend`].

#![allow(unsafe_op_in_unsafe_fn)]

use core::arch::x86_64::*;

/// Dual-lane 256-bit register backed by two `__m128i`.
#[derive(Clone, Copy)]
pub struct X86Simd256u8 {
    pub lo: __m128i,
    pub hi: __m128i,
}

/// Dual-lane u16 accumulator backed by two `__m128i` (8 u16 lanes each…
/// bundled twice → 16 lanes, matching [`crate::simd::Simd256u16`]).
#[derive(Clone, Copy)]
pub struct X86Simd256u16 {
    pub lo: __m128i,
    pub hi: __m128i,
}

impl X86Simd256u8 {
    /// Load 32 bytes (unaligned).
    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn load(p: *const u8) -> Self {
        Self {
            lo: _mm_loadu_si128(p as *const __m128i),
            hi: _mm_loadu_si128(p.add(16) as *const __m128i),
        }
    }

    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn splat(x: u8) -> Self {
        let v = _mm_set1_epi8(x as i8);
        Self { lo: v, hi: v }
    }

    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn store(self, out: *mut u8) {
        _mm_storeu_si128(out as *mut __m128i, self.lo);
        _mm_storeu_si128(out.add(16) as *mut __m128i, self.hi);
    }

    /// Dual-table shuffle: `pshufb(T¹, idx.lo)` / `pshufb(T², idx.hi)`.
    /// Indices must already be masked to `0..16`.
    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn shuffle_dual(tables: Self, idx: Self) -> Self {
        Self { lo: _mm_shuffle_epi8(tables.lo, idx.lo), hi: _mm_shuffle_epi8(tables.hi, idx.hi) }
    }

    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn and(self, other: Self) -> Self {
        Self { lo: _mm_and_si128(self.lo, other.lo), hi: _mm_and_si128(self.hi, other.hi) }
    }

    /// Logical shift right by 4 within each byte (via u16 shift + mask).
    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn shr4(self) -> Self {
        let m = _mm_set1_epi8(0x0F);
        Self {
            lo: _mm_and_si128(_mm_srli_epi16(self.lo, 4), m),
            hi: _mm_and_si128(_mm_srli_epi16(self.hi, 4), m),
        }
    }

    /// `_mm_movemask_epi8` on both lanes → 32-bit mask.
    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn movemask(self) -> u32 {
        (_mm_movemask_epi8(self.lo) as u32 & 0xFFFF)
            | ((_mm_movemask_epi8(self.hi) as u32) << 16)
    }

    /// Zero-extend the 32 u8 lanes to two 16-lane u16 registers.
    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn widen(self) -> (X86Simd256u16, X86Simd256u16) {
        let z = _mm_setzero_si128();
        (
            X86Simd256u16 {
                lo: _mm_unpacklo_epi8(self.lo, z),
                hi: _mm_unpackhi_epi8(self.lo, z),
            },
            X86Simd256u16 {
                lo: _mm_unpacklo_epi8(self.hi, z),
                hi: _mm_unpackhi_epi8(self.hi, z),
            },
        )
    }
}

impl X86Simd256u16 {
    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn zero() -> Self {
        let z = _mm_setzero_si128();
        Self { lo: z, hi: z }
    }

    /// Saturating u16 accumulate (`_mm_adds_epu16`).
    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn sat_add(self, other: Self) -> Self {
        Self { lo: _mm_adds_epu16(self.lo, other.lo), hi: _mm_adds_epu16(self.hi, other.hi) }
    }

    #[inline]
    #[target_feature(enable = "ssse3")]
    pub unsafe fn store(self, out: *mut u16) {
        _mm_storeu_si128(out as *mut __m128i, self.lo);
        _mm_storeu_si128(out.add(8) as *mut __m128i, self.hi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simd::{best_backend, Backend, Simd256u8};
    use crate::util::rng::Rng;

    fn have_ssse3() -> bool {
        best_backend() == Backend::Ssse3
    }

    /// Differential test: the x86 backend must agree with the portable
    /// NEON-semantics model on the masked-index domain used by fastscan.
    #[test]
    fn shuffle_dual_matches_portable() {
        if !have_ssse3() {
            eprintln!("skipping: no ssse3");
            return;
        }
        let mut rng = Rng::new(77);
        for _ in 0..500 {
            let mut tables = [0u8; 32];
            let mut idx = [0u8; 32];
            for b in &mut tables {
                *b = (rng.next_u32() & 0xFF) as u8;
            }
            for b in &mut idx {
                *b = (rng.next_u32() % 16) as u8; // masked domain
            }
            // portable
            let pt = Simd256u8::load(&tables);
            let pi = Simd256u8::load(&idx);
            let mut expect = [0u8; 32];
            Simd256u8::shuffle_dual(pt, pi).store(&mut expect);
            // x86
            let mut got = [0u8; 32];
            unsafe {
                let xt = X86Simd256u8::load(tables.as_ptr());
                let xi = X86Simd256u8::load(idx.as_ptr());
                X86Simd256u8::shuffle_dual(xt, xi).store(got.as_mut_ptr());
            }
            assert_eq!(got, expect);
        }
    }

    #[test]
    fn nibble_and_widen_match_portable() {
        if !have_ssse3() {
            eprintln!("skipping: no ssse3");
            return;
        }
        let mut rng = Rng::new(78);
        for _ in 0..200 {
            let mut packed = [0u8; 32];
            for b in &mut packed {
                *b = (rng.next_u32() & 0xFF) as u8;
            }
            // portable reference
            let c = Simd256u8::load(&packed);
            let mask = Simd256u8::splat(0x0F);
            let mut lo_e = [0u8; 32];
            let mut hi_e = [0u8; 32];
            c.and(mask).store(&mut lo_e);
            c.shr4().and(mask).store(&mut hi_e);
            let (w0, w1) = c.widen();
            let mut w0_e = [0u16; 16];
            let mut w1_e = [0u16; 16];
            w0.store(&mut w0_e);
            w1.store(&mut w1_e);
            // x86
            unsafe {
                let xc = X86Simd256u8::load(packed.as_ptr());
                let xm = X86Simd256u8::splat(0x0F);
                let mut lo_g = [0u8; 32];
                let mut hi_g = [0u8; 32];
                xc.and(xm).store(lo_g.as_mut_ptr());
                xc.shr4().and(xm).store(hi_g.as_mut_ptr());
                assert_eq!(lo_g, lo_e);
                assert_eq!(hi_g, hi_e);
                let (x0, x1) = xc.widen();
                let mut w0_g = [0u16; 16];
                let mut w1_g = [0u16; 16];
                x0.store(w0_g.as_mut_ptr());
                x1.store(w1_g.as_mut_ptr());
                assert_eq!(w0_g, w0_e);
                assert_eq!(w1_g, w1_e);
            }
        }
    }

    #[test]
    fn sat_add_matches_portable() {
        if !have_ssse3() {
            eprintln!("skipping: no ssse3");
            return;
        }
        unsafe {
            let a = X86Simd256u16 {
                lo: _mm_set1_epi16(-1536i16), // 64000 as u16
                hi: _mm_set1_epi16(1000),
            };
            let b = X86Simd256u16 { lo: _mm_set1_epi16(5000), hi: _mm_set1_epi16(2000) };
            let mut out = [0u16; 16];
            a.sat_add(b).store(out.as_mut_ptr());
            assert_eq!(out[..8], [u16::MAX; 8]); // 64000 + 5000 saturates
            assert_eq!(out[8..], [3000u16; 8]);
        }
    }

    #[test]
    fn movemask_matches_portable() {
        if !have_ssse3() {
            eprintln!("skipping: no ssse3");
            return;
        }
        let mut rng = Rng::new(79);
        for _ in 0..200 {
            let mut b = [0u8; 32];
            for x in &mut b {
                *x = (rng.next_u32() & 0xFF) as u8;
            }
            let expect = Simd256u8::load(&b).movemask();
            let got = unsafe { X86Simd256u8::load(b.as_ptr()).movemask() };
            assert_eq!(got, expect);
        }
    }
}
