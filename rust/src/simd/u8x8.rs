//! ARMv7 64-bit register model (`uint8x8_t` D-registers).
//!
//! Paper §3: *"only 64- and 128-bit SIMD registers are available for ARMv7
//! and ARMv8, respectively."* The ARMv8 path bundles two 128-bit Q-registers
//! into a virtual 256-bit register; this module models the ARMv7 fallback —
//! **four 64-bit D-registers** per virtual 256-bit value, with `vtbl1_u8`
//! (the 8-lane table lookup that consults a 64-bit table) as the shuffle.
//!
//! Because `vtbl1_u8` can only address an 8-entry table, a 16-entry LUT
//! needs the two-register form `vtbl2_u8` (table pair); both are modeled.
//! The quad-lane fastscan variant built on this is benchmarked in
//! `kernel_micro` as the ARMv7 ablation.

/// ARMv7 `uint8x8_t`: eight u8 lanes (one D-register).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
#[repr(align(8))]
pub struct U8x8(pub [u8; 8]);

/// `vld1_u8`: load 8 bytes.
#[inline(always)]
pub fn vld1_u8(p: &[u8]) -> U8x8 {
    let mut out = [0u8; 8];
    out.copy_from_slice(&p[..8]);
    U8x8(out)
}

/// `vdup_n_u8`: broadcast.
#[inline(always)]
pub fn vdup_n_u8(x: u8) -> U8x8 {
    U8x8([x; 8])
}

/// `vtbl1_u8`: 8-entry table lookup; indices ≥ 8 yield 0 (Arm ISA).
#[inline(always)]
pub fn vtbl1_u8(table: U8x8, idx: U8x8) -> U8x8 {
    let mut out = [0u8; 8];
    for i in 0..8 {
        let j = idx.0[i];
        out[i] = if j < 8 { table.0[j as usize] } else { 0 };
    }
    U8x8(out)
}

/// `vtbl2_u8`: 16-entry lookup over a D-register *pair* — this is how a
/// 16-entry 4-bit-PQ table is consulted on ARMv7. Indices ≥ 16 yield 0.
#[inline(always)]
pub fn vtbl2_u8(table: [U8x8; 2], idx: U8x8) -> U8x8 {
    let mut out = [0u8; 8];
    for i in 0..8 {
        let j = idx.0[i] as usize;
        out[i] = if j < 8 {
            table[0].0[j]
        } else if j < 16 {
            table[1].0[j - 8]
        } else {
            0
        };
    }
    U8x8(out)
}

/// `vand_u8` / `vshr_n_u8`: nibble extraction primitives.
#[inline(always)]
pub fn vand_u8(a: U8x8, b: U8x8) -> U8x8 {
    let mut out = [0u8; 8];
    for i in 0..8 {
        out[i] = a.0[i] & b.0[i];
    }
    U8x8(out)
}

#[inline(always)]
pub fn vshr_n_u8<const N: i32>(a: U8x8) -> U8x8 {
    let mut out = [0u8; 8];
    for i in 0..8 {
        out[i] = a.0[i] >> N;
    }
    U8x8(out)
}

/// `vaddl_u8`-style widening accumulate into 8 u16 lanes (saturating, to
/// match the ARMv8 kernel's accumulator semantics).
#[inline(always)]
pub fn acc_sat_u16(acc: &mut [u16; 8], x: U8x8) {
    for i in 0..8 {
        acc[i] = acc[i].saturating_add(x.0[i] as u16);
    }
}

/// ARMv7 fastscan block kernel: identical math to the ARMv8 dual-lane
/// kernel but built from **four** 64-bit lanes per virtual 256-bit value
/// and `vtbl2_u8` lookups. One 32-byte pair chunk = 4 D-register loads.
pub fn accumulate_block_armv7(
    block: &[u8],
    luts: &crate::pq::fastscan::KernelLuts,
    out: &mut [u16; crate::pq::BLOCK_SIZE],
) {
    debug_assert_eq!(
        luts.wiring,
        crate::pq::fastscan::LaneWiring::PairedTables,
        "the ARMv7 model covers the paired (2-/4-bit) wiring only"
    );
    let npairs = luts.chunks();
    let mask = vdup_n_u8(0x0F);
    // accumulators: 4 × 8 u16 lanes (vectors 0..32)
    let mut acc = [[0u16; 8]; 4];
    for p in 0..npairs {
        let chunk = &luts.bytes[p * 32..(p + 1) * 32];
        let t_q: [U8x8; 2] = [vld1_u8(&chunk[0..8]), vld1_u8(&chunk[8..16])];
        let t_q1: [U8x8; 2] = [vld1_u8(&chunk[16..24]), vld1_u8(&chunk[24..32])];
        let code_chunk = &block[p * 32..(p + 1) * 32];
        // bytes 0..16 hold sub-quantizer q codes (lo nibble v0..15, hi v16..31)
        // bytes 16..32 hold q+1 — each consumed as two D-registers.
        for half in 0..2 {
            let c = vld1_u8(&code_chunk[half * 8..half * 8 + 8]); // subq q, v(8h)..v(8h+8)
            let c1 = vld1_u8(&code_chunk[16 + half * 8..16 + half * 8 + 8]); // subq q+1
            let lo = vand_u8(c, mask);
            let hi = vshr_n_u8::<4>(c);
            let lo1 = vand_u8(c1, mask);
            let hi1 = vshr_n_u8::<4>(c1);
            // v(8h)..(8h+8): contributions of q and q+1
            acc_sat_u16(&mut acc[half], vtbl2_u8(t_q, lo));
            acc_sat_u16(&mut acc[half], vtbl2_u8(t_q1, lo1));
            // v(16+8h)..: the high-nibble codes
            acc_sat_u16(&mut acc[2 + half], vtbl2_u8(t_q, hi));
            acc_sat_u16(&mut acc[2 + half], vtbl2_u8(t_q1, hi1));
        }
    }
    for h in 0..4 {
        out[h * 8..(h + 1) * 8].copy_from_slice(&acc[h]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::fastscan::{accumulate_block_portable, KernelLuts};
    use crate::pq::lut::QuantizedLuts;
    use crate::pq::{CodeWidth, PackedCodes, BLOCK_SIZE};
    use crate::util::rng::Rng;

    #[test]
    fn vtbl1_semantics() {
        let t = U8x8([10, 11, 12, 13, 14, 15, 16, 17]);
        let idx = U8x8([0, 7, 3, 8, 255, 2, 1, 100]);
        assert_eq!(vtbl1_u8(t, idx).0, [10, 17, 13, 0, 0, 12, 11, 0]);
    }

    #[test]
    fn vtbl2_covers_16_entries() {
        let t = [U8x8([0, 1, 2, 3, 4, 5, 6, 7]), U8x8([8, 9, 10, 11, 12, 13, 14, 15])];
        for j in 0..16u8 {
            let out = vtbl2_u8(t, vdup_n_u8(j));
            assert_eq!(out.0, [j; 8]);
        }
        assert_eq!(vtbl2_u8(t, vdup_n_u8(16)).0, [0; 8]);
    }

    /// The ARMv7 quad-64-bit kernel must agree exactly with the ARMv8
    /// dual-128-bit kernel — the paper's claim that the bundling trick is
    /// register-width independent.
    #[test]
    fn armv7_kernel_matches_armv8_kernel() {
        let mut rng = Rng::new(222);
        for &m in &[2usize, 4, 8, 16, 32] {
            let n = BLOCK_SIZE;
            let codes: Vec<u8> = (0..n * m).map(|_| (rng.next_u32() % 16) as u8).collect();
            let luts_f32: Vec<f32> = (0..m * 16).map(|_| rng.next_f32() * 7.0).collect();
            let qluts = QuantizedLuts::from_f32(&luts_f32, m, 16);
            let packed = PackedCodes::pack(&codes, m, CodeWidth::W4).unwrap();
            let kluts = KernelLuts::build(&qluts, packed.lut_rows);
            let block = &packed.data[..packed.block_bytes()];
            let mut v8 = [0u16; BLOCK_SIZE];
            let mut v7 = [0u16; BLOCK_SIZE];
            accumulate_block_portable(block, &kluts, &mut v8);
            accumulate_block_armv7(block, &kluts, &mut v7);
            assert_eq!(v7, v8, "m={m}");
        }
    }
}
