//! [`CodeStore`]: where packed code bytes live — heap or mapped file.

use super::mmap::Mmap;
use crate::{Error, Result};
use std::ops::Deref;
use std::sync::Arc;

/// Ownership of one packed-code region.
///
/// `Owned` is the historical behaviour: codes packed in memory or copied
/// out of an index file. `Mapped` is a window into a shared read-only
/// [`Mmap`] of a v3 index file — cloning bumps an `Arc`, the bytes stay
/// in the page cache, and every process mapping the same file shares
/// them. Both deref to `&[u8]`, so kernel code never branches on the
/// variant.
#[derive(Clone)]
pub enum CodeStore {
    Owned(Vec<u8>),
    Mapped { map: Arc<Mmap>, offset: usize, len: usize },
}

impl CodeStore {
    /// A bounds-checked window into `map`. v3 regions are 64-byte
    /// aligned in the file; the offset check turns a corrupt header into
    /// a clean error instead of an out-of-bounds slice later.
    pub fn from_mapped(map: Arc<Mmap>, offset: usize, len: usize) -> Result<CodeStore> {
        let end = offset.checked_add(len).ok_or_else(|| {
            Error::CorruptIndex(format!("code region {offset}+{len} overflows"))
        })?;
        if end > map.len() {
            return Err(Error::CorruptIndex(format!(
                "code region [{offset}, {end}) exceeds mapped file of {} bytes",
                map.len()
            )));
        }
        Ok(CodeStore::Mapped { map, offset, len })
    }

    pub fn len(&self) -> usize {
        match self {
            CodeStore::Owned(v) => v.len(),
            CodeStore::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether these bytes are served zero-copy from a mapped file.
    pub fn is_mapped(&self) -> bool {
        matches!(self, CodeStore::Mapped { .. })
    }

    /// Bytes backed by a mapped file (0 for `Owned`) — feeds the
    /// `bytes_mapped` query stat.
    pub fn mapped_bytes(&self) -> usize {
        match self {
            CodeStore::Owned(_) => 0,
            CodeStore::Mapped { len, .. } => *len,
        }
    }

    /// The backing map, if any — used by the residency policy at open
    /// time to advise this region's pages.
    pub fn mapped_region(&self) -> Option<(&Arc<Mmap>, usize, usize)> {
        match self {
            CodeStore::Owned(_) => None,
            CodeStore::Mapped { map, offset, len } => Some((map, *offset, *len)),
        }
    }
}

impl Deref for CodeStore {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        match self {
            CodeStore::Owned(v) => v,
            CodeStore::Mapped { map, offset, len } => &map[*offset..*offset + *len],
        }
    }
}

impl Default for CodeStore {
    fn default() -> Self {
        CodeStore::Owned(Vec::new())
    }
}

impl From<Vec<u8>> for CodeStore {
    fn from(v: Vec<u8>) -> Self {
        CodeStore::Owned(v)
    }
}

impl std::fmt::Debug for CodeStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodeStore::Owned(v) => write!(f, "CodeStore::Owned({} bytes)", v.len()),
            CodeStore::Mapped { offset, len, .. } => {
                write!(f, "CodeStore::Mapped({len} bytes @ {offset})")
            }
        }
    }
}

// Equality is by content: a mapped region equals the owned copy of the
// same bytes, which is exactly what the differential tests assert.
impl PartialEq for CodeStore {
    fn eq(&self, other: &Self) -> bool {
        self[..] == other[..]
    }
}
impl Eq for CodeStore {}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_map(bytes: &[u8]) -> (std::path::PathBuf, Arc<Mmap>) {
        let dir = std::env::temp_dir().join(format!("armpq_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("s{}.bin", bytes.len()));
        std::fs::write(&path, bytes).unwrap();
        let map = Arc::new(Mmap::open(&path).unwrap());
        (path, map)
    }

    #[test]
    fn owned_and_mapped_deref_identically() {
        let bytes: Vec<u8> = (0..1000u32).map(|i| (i % 251) as u8).collect();
        let owned = CodeStore::from(bytes.clone());
        let (path, map) = tmp_map(&bytes);
        let mapped = CodeStore::from_mapped(map, 0, bytes.len()).unwrap();
        assert_eq!(&owned[..], &bytes[..]);
        assert_eq!(&mapped[..], &bytes[..]);
        assert_eq!(owned, mapped);
        assert!(!owned.is_mapped());
        assert!(mapped.is_mapped());
        assert_eq!(owned.mapped_bytes(), 0);
        assert_eq!(mapped.mapped_bytes(), bytes.len());
        // windowed view
        let window = CodeStore::from_mapped(
            mapped.mapped_region().unwrap().0.clone(),
            100,
            200,
        )
        .unwrap();
        assert_eq!(&window[..], &bytes[100..300]);
        drop(mapped);
        drop(window);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mapped_window_is_bounds_checked() {
        let (path, map) = tmp_map(&[0u8; 128]);
        assert!(CodeStore::from_mapped(map.clone(), 0, 129).is_err());
        assert!(CodeStore::from_mapped(map.clone(), 64, 65).is_err());
        assert!(CodeStore::from_mapped(map.clone(), usize::MAX, 2).is_err());
        assert!(CodeStore::from_mapped(map, 128, 0).is_ok()); // empty tail is fine
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn clone_shares_the_map() {
        let (path, map) = tmp_map(&[7u8; 256]);
        let a = CodeStore::from_mapped(map.clone(), 0, 256).unwrap();
        let b = a.clone();
        drop(map);
        assert_eq!(&a[..], &b[..]);
        assert_eq!(format!("{a:?}"), "CodeStore::Mapped(256 bytes @ 0)");
        drop((a, b));
        std::fs::remove_file(&path).unwrap();
    }
}
