//! Residency policy and process-wide storage gauges.

use super::mmap::Mmap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide storage gauges, surfaced by the coordinator's `stats`
/// verb (see `coordinator/metrics.rs`). Maps update them on open/close;
/// [`MemoryBudget`] updates the resident gauge through its advice calls.
#[derive(Debug)]
pub struct StorageCounters {
    mapped_code_bytes: AtomicU64,
    resident_code_bytes: AtomicU64,
    resident_sampled_bytes: AtomicU64,
    mmap_open_total: AtomicU64,
}

impl StorageCounters {
    /// Bytes currently memory-mapped (current gauge, not cumulative).
    pub fn mapped_code_bytes(&self) -> u64 {
        self.mapped_code_bytes.load(Ordering::Relaxed)
    }

    /// Bytes currently advised resident (WILLNEED) across live maps —
    /// the budget-admitted working set, an upper-bound estimate of the
    /// code pages this process asked the kernel to keep warm.
    pub fn resident_code_bytes(&self) -> u64 {
        self.resident_code_bytes.load(Ordering::Relaxed)
    }

    /// Bytes of live mapped regions the kernel actually held in RAM at
    /// the last [`super::mmap::sample_residency`] call (`mincore`
    /// ground truth, stride-sampled for very large maps) — versus
    /// [`StorageCounters::resident_code_bytes`], which only tracks what
    /// this process *advised*.
    pub fn resident_sampled_bytes(&self) -> u64 {
        self.resident_sampled_bytes.load(Ordering::Relaxed)
    }

    /// Maps opened over the process lifetime (monotonic counter).
    pub fn mmap_open_total(&self) -> u64 {
        self.mmap_open_total.load(Ordering::Relaxed)
    }

    pub(crate) fn note_map_open(&self, len: usize) {
        self.mmap_open_total.fetch_add(1, Ordering::Relaxed);
        self.mapped_code_bytes.fetch_add(len as u64, Ordering::Relaxed);
    }

    pub(crate) fn note_map_close(&self, len: usize, resident: usize) {
        self.mapped_code_bytes.fetch_sub(len as u64, Ordering::Relaxed);
        self.resident_code_bytes.fetch_sub(resident as u64, Ordering::Relaxed);
    }

    pub(crate) fn note_resident(&self, delta: i64) {
        if delta >= 0 {
            self.resident_code_bytes.fetch_add(delta as u64, Ordering::Relaxed);
        } else {
            self.resident_code_bytes.fetch_sub((-delta) as u64, Ordering::Relaxed);
        }
    }

    pub(crate) fn note_resident_sampled(&self, bytes: u64) {
        self.resident_sampled_bytes.store(bytes, Ordering::Relaxed);
    }
}

/// The process-wide gauge registry.
pub fn counters() -> &'static StorageCounters {
    static COUNTERS: StorageCounters = StorageCounters {
        mapped_code_bytes: AtomicU64::new(0),
        resident_code_bytes: AtomicU64::new(0),
        resident_sampled_bytes: AtomicU64::new(0),
        mmap_open_total: AtomicU64::new(0),
    };
    &COUNTERS
}

/// Residency policy for one mapped open: admit code regions (WILLNEED)
/// in file order until the byte budget is spent, explicitly release
/// (DONTNEED) everything past it. Without a cap every region is
/// admitted.
///
/// The policy is advice, not enforcement — a query that touches
/// non-admitted codes still works, it just pages them in on first scan.
/// That is exactly the behaviour the budget-capped differential test
/// pins down: capped opens answer bit-identically, only colder.
#[derive(Debug)]
pub struct MemoryBudget {
    limit: Option<u64>,
    admitted: u64,
}

impl MemoryBudget {
    /// No cap: every code region is advised resident.
    pub fn unlimited() -> Self {
        Self { limit: None, admitted: 0 }
    }

    /// A cap in MiB (`None` = unlimited) — the `budget_mb=…` open option.
    pub fn from_mb(mb: Option<u64>) -> Self {
        Self { limit: mb.map(|m| m.saturating_mul(1024 * 1024)), admitted: 0 }
    }

    /// Bytes admitted (advised resident) so far.
    pub fn admitted_bytes(&self) -> u64 {
        self.admitted
    }

    /// Apply the policy to one code region of `map`; returns how many of
    /// its bytes were admitted.
    pub fn admit_region(&mut self, map: &Mmap, offset: usize, len: usize) -> usize {
        let take = match self.limit {
            None => len,
            Some(limit) => (limit.saturating_sub(self.admitted) as usize).min(len),
        };
        if take > 0 {
            map.advise_resident(offset, take, true);
            self.admitted += take as u64;
        }
        if take < len {
            map.advise_resident(offset + take, len - take, false);
        }
        take
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_map(len: usize) -> (std::path::PathBuf, Mmap) {
        let dir = std::env::temp_dir().join(format!("armpq_budget_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("b{len}.bin"));
        std::fs::write(&path, vec![0xABu8; len]).unwrap();
        let map = Mmap::open(&path).unwrap();
        (path, map)
    }

    #[test]
    fn unlimited_admits_everything() {
        let (path, map) = tmp_map(200_000);
        let mut b = MemoryBudget::unlimited();
        assert_eq!(b.admit_region(&map, 0, 150_000), 150_000);
        assert_eq!(b.admit_region(&map, 150_000, 50_000), 50_000);
        assert_eq!(b.admitted_bytes(), 200_000);
        drop(map);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn capped_budget_stops_admitting() {
        let (path, map) = tmp_map(4 * 1024 * 1024);
        let mut b = MemoryBudget::from_mb(Some(1)); // 1 MiB
        let first = b.admit_region(&map, 0, 3 * 1024 * 1024);
        assert_eq!(first, 1024 * 1024, "cap ignored");
        // budget exhausted: later regions are fully released
        let second = b.admit_region(&map, 3 * 1024 * 1024, 1024 * 1024);
        assert_eq!(second, 0);
        assert_eq!(b.admitted_bytes(), 1024 * 1024);
        drop(map);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn gauges_move_with_map_lifecycle() {
        let before_mapped = counters().mapped_code_bytes();
        let (path, map) = tmp_map(64 * 1024);
        assert!(counters().mapped_code_bytes() >= before_mapped + 64 * 1024);
        let mut b = MemoryBudget::unlimited();
        b.admit_region(&map, 0, 64 * 1024);
        drop(map);
        // close subtracts both the mapped and the resident share
        assert!(counters().mapped_code_bytes() >= before_mapped);
        std::fs::remove_file(&path).unwrap();
    }
}
