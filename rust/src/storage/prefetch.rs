//! Software prefetch helpers for the scan loop.
//!
//! The per-list candidate discipline scans probed lists one after
//! another; while the kernels chew on list *i*, issuing prefetch hints
//! for list *i + 1* hides both the page-in cost of a mapped region that
//! is not yet resident and the cache-fill cost of one that is. All
//! hints are best-effort: on targets without a prefetch instruction
//! they compile to nothing.

/// How far ahead of the scan a single [`prefetch_span`] call walks, in
/// bytes. One probed IVF list is usually a few KiB of packed codes;
/// 4 KiB (one base page, 64 cache lines) is enough to cover the head of
/// the next list without evicting the current one's working set.
pub const PREFETCH_SPAN_BYTES: usize = 4096;

/// Hint that the cache line containing `ptr` will be read soon.
#[inline(always)]
pub fn prefetch_read(ptr: *const u8) {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        use core::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(ptr as *const i8);
    }
    #[cfg(target_arch = "aarch64")]
    unsafe {
        // `core::arch::aarch64::_prefetch` is nightly-only; the
        // instruction itself is not. PLD L1 "keep" matches x86's T0.
        core::arch::asm!(
            "prfm pldl1keep, [{0}]",
            in(reg) ptr,
            options(readonly, nostack, preserves_flags)
        );
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        let _ = ptr;
    }
}

/// Prefetch the head of `bytes` — up to [`PREFETCH_SPAN_BYTES`] — in
/// cache-line strides. Returns how many bytes were covered so callers
/// can account prefetch work in stats.
#[inline]
pub fn prefetch_span(bytes: &[u8]) -> usize {
    let span = bytes.len().min(PREFETCH_SPAN_BYTES);
    let base = bytes.as_ptr();
    let mut off = 0usize;
    while off < span {
        // Safety: `base + off` stays strictly inside `bytes` (off < span
        // <= len), and prefetch has no observable effect regardless.
        prefetch_read(unsafe { base.add(off) });
        off += 64;
    }
    span
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_covers_min_of_len_and_cap() {
        let small = vec![1u8; 100];
        assert_eq!(prefetch_span(&small), 100);
        let big = vec![2u8; 3 * PREFETCH_SPAN_BYTES];
        assert_eq!(prefetch_span(&big), PREFETCH_SPAN_BYTES);
        assert_eq!(prefetch_span(&[]), 0);
    }

    #[test]
    fn prefetch_is_side_effect_free() {
        let data = vec![0xCDu8; 8192];
        prefetch_span(&data);
        prefetch_read(data.as_ptr());
        assert!(data.iter().all(|&b| b == 0xCD));
    }
}
