//! Zero-copy storage layer: memory-mapped code regions, residency
//! budgeting, and software prefetch for the scan loop.
//!
//! # Why this layer exists
//!
//! The fastscan kernels assume their packed code blocks are resident; at
//! billion-vector scale nothing above this layer can afford to *make*
//! them resident by copying every segment into the heap at load time.
//! Format v3 (see [`crate::index::io`]) therefore lays packed code
//! regions out 64-byte-aligned inside the index file so a loader can
//! [`Mmap`] the file once and hand each region to the kernels in place —
//! page-cache pages are shared across processes, opens are O(metadata),
//! and the OS pages codes in on first scan instead of up front.
//!
//! # The Owned/Mapped ownership model
//!
//! [`CodeStore`] is the single ownership abstraction under
//! [`crate::pq::PackedCodes`]:
//!
//! * `Owned(Vec<u8>)` — built in memory (`pack`) or heap-loaded; the
//!   historical behaviour, still the default.
//! * `Mapped { map, offset, len }` — a window into a shared [`Mmap`] of
//!   the index file. Cloning clones an `Arc`, not the bytes, so one
//!   mapped file backs every segment of a loaded index.
//!
//! Both deref to `&[u8]`, so the kernels (and every existing test that
//! indexes `packed.data[..]`) are oblivious to where the bytes live.
//!
//! # Why alignment is load-bearing
//!
//! The dual-lane kernels consume codes in 32-vector blocks of
//! `lut_rows × 16` bytes through 128-bit table-lookup instructions
//! (`pshufb` / `vqtbl1q_u8`). A block that straddles a cache line costs
//! an extra fill per shuffle on in-order ARM cores, and unaligned SIMD
//! loads forfeit the single-µop fast path on several Neoverse
//! generations. v3 pads every code region to a 64-byte boundary —
//! combined with the page-aligned base address `mmap` guarantees, every
//! block starts on a cache-line boundary, mapped or heap-loaded alike.
//!
//! # Residency: [`MemoryBudget`] and prefetch
//!
//! A mapped index larger than RAM needs residency *policy*, not hope:
//! [`MemoryBudget`] walks the code regions at open time and advises the
//! kernel (`madvise(WILLNEED)`) up to the configured budget, explicitly
//! releasing the remainder (`DONTNEED`) so a capped open never evicts
//! the hot set to warm the cold one. At query time the scan loop issues
//! software prefetch ([`prefetch_span`]) for the *next* probed list one
//! list ahead, hiding page-in and cache-fill latency behind the current
//! list's arithmetic. Global gauges ([`counters`]) expose
//! `mapped_code_bytes` / `resident_code_bytes` / `mmap_open_total` to
//! the coordinator's `stats` verb.

mod budget;
mod mmap;
mod prefetch;
mod store;

pub use budget::{counters, MemoryBudget, StorageCounters};
pub use mmap::{sample_residency, Mmap};
pub use prefetch::{prefetch_read, prefetch_span, PREFETCH_SPAN_BYTES};
pub use store::CodeStore;

use crate::{Error, Result};

/// How an index file should be opened: heap-copied (the default, always
/// available) or memory-mapped with an optional residency budget.
///
/// Parsed from trailing `key=value` factory-string parts
/// (`"IVF100,PQ16x4fs,mmap=true,budget_mb=512"`) and from coordinator
/// config keys of the same names. `budget_mb` only applies to mapped
/// opens; a heap open always materializes everything.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpenOptions {
    /// Map code regions zero-copy instead of reading them into the heap.
    pub mmap: bool,
    /// Residency budget in MiB for mapped code regions (`None` =
    /// unlimited: advise everything resident).
    pub budget_mb: Option<u64>,
}

impl OpenOptions {
    /// Heap-loading options (the v1/v2-compatible default).
    pub fn heap() -> Self {
        Self::default()
    }

    /// Zero-copy mapped open with no residency cap.
    pub fn mapped() -> Self {
        Self { mmap: true, budget_mb: None }
    }

    /// Try to consume one `key=value` pair. Returns `Ok(true)` when the
    /// key is a storage option (`mmap` / `budget_mb`), `Ok(false)` when
    /// it belongs to someone else, and an error for a storage key with
    /// an unparseable value.
    pub fn assign(&mut self, key: &str, value: &str) -> Result<bool> {
        match key {
            "mmap" => {
                self.mmap = value.parse::<bool>().map_err(|_| {
                    Error::InvalidParameter(format!("mmap={value} (expected true|false)"))
                })?;
                Ok(true)
            }
            "budget_mb" => {
                let mb = value.parse::<u64>().map_err(|_| {
                    Error::InvalidParameter(format!("budget_mb={value} (expected an integer)"))
                })?;
                self.budget_mb = Some(mb);
                Ok(true)
            }
            _ => Ok(false),
        }
    }

    /// The residency budget these options imply for one open.
    pub fn budget(&self) -> MemoryBudget {
        MemoryBudget::from_mb(self.budget_mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_options_assign() {
        let mut o = OpenOptions::default();
        assert!(!o.mmap);
        assert!(o.assign("mmap", "true").unwrap());
        assert!(o.assign("budget_mb", "64").unwrap());
        assert_eq!(o, OpenOptions { mmap: true, budget_mb: Some(64) });
        // non-storage keys are left for the caller
        assert!(!o.assign("nprobe", "8").unwrap());
        // bad values on storage keys are hard errors
        assert!(o.assign("mmap", "maybe").is_err());
        assert!(o.assign("budget_mb", "lots").is_err());
    }
}
