//! Read-only whole-file memory map with RAII unmap and best-effort
//! residency advice.
//!
//! The crate is dependency-free, so on 64-bit unix targets the
//! `mmap`/`munmap`/`madvise` bindings are declared by hand — std already
//! links libc there, so they resolve without adding a crate. Every other
//! target gets a transparent fallback that reads the file into the heap
//! behind the same API (no zero-copy, but identical semantics).

use super::budget::counters;
use crate::{Error, Result};
use std::ops::Deref;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Advice alignment: `madvise` wants page-aligned addresses, and the
/// largest page size in common use (aarch64 64K-page kernels) divides
/// this, so rounding region starts down to a 64 KiB boundary is aligned
/// on every supported host without querying the page size.
const ADVISE_ALIGN: usize = 64 * 1024;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    // Hand-declared libc bindings (see the module doc for why).
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
        pub fn madvise(addr: *mut u8, len: usize, advice: i32) -> i32;
        pub fn mincore(addr: *mut u8, len: usize, vec: *mut u8) -> i32;
        pub fn sysconf(name: i32) -> i64;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_SHARED: i32 = 1;
    // identical numeric values on linux and the BSD family (incl. macOS)
    pub const MADV_WILLNEED: i32 = 3;
    pub const MADV_DONTNEED: i32 = 4;
    // _SC_PAGESIZE differs between the families
    #[cfg(target_os = "linux")]
    pub const SC_PAGESIZE: i32 = 30;
    #[cfg(not(target_os = "linux"))]
    pub const SC_PAGESIZE: i32 = 29;
}

/// Live mapped regions `(base, len)`, maintained by [`Mmap`]'s
/// open/drop so [`sample_residency`] can walk every mapping the process
/// currently holds without the maps having to know about each other.
#[cfg(all(unix, target_pointer_width = "64"))]
fn regions() -> &'static std::sync::Mutex<Vec<(usize, usize)>> {
    static REGIONS: std::sync::Mutex<Vec<(usize, usize)>> = std::sync::Mutex::new(Vec::new());
    &REGIONS
}

/// Regions with at most this many pages are `mincore`d in full (one
/// syscall, one byte per page); larger ones are stride-sampled.
#[cfg(all(unix, target_pointer_width = "64"))]
const MINCORE_FULL_PAGES: usize = 1 << 16;

/// Evenly spaced single-page probes for oversized regions.
#[cfg(all(unix, target_pointer_width = "64"))]
const MINCORE_SAMPLE_PROBES: usize = 512;

/// Measure (by `mincore`) how many bytes of the process's live mapped
/// code regions the kernel actually holds in RAM right now, and publish
/// the total to the `resident_sampled_bytes` gauge. Unlike
/// `resident_code_bytes` (what we *advised*), this is ground truth —
/// the kernel may have evicted advised pages under pressure, or faulted
/// in never-advised ones on first scan.
///
/// Small regions are measured exactly; regions above
/// ~[`MINCORE_FULL_PAGES`] pages are stride-sampled and extrapolated.
/// Returns the sampled resident byte total.
#[cfg(all(unix, target_pointer_width = "64"))]
pub fn sample_residency() -> u64 {
    let page = unsafe { sys::sysconf(sys::SC_PAGESIZE) };
    let page = if page > 0 { page as usize } else { 4096 };
    let snapshot: Vec<(usize, usize)> = regions().lock().unwrap().clone();
    let mut resident = 0u64;
    let mut vec_buf: Vec<u8> = Vec::new();
    for (base, len) in snapshot {
        let npages = len.div_ceil(page);
        if npages == 0 {
            continue;
        }
        if npages <= MINCORE_FULL_PAGES {
            vec_buf.clear();
            vec_buf.resize(npages, 0);
            let rc = unsafe { sys::mincore(base as *mut u8, len, vec_buf.as_mut_ptr()) };
            if rc == 0 {
                let hits = vec_buf.iter().filter(|&&b| b & 1 != 0).count();
                // the last page may be partial; count pages, cap at len
                resident += ((hits * page).min(len)) as u64;
            }
        } else {
            // stride sample: probe evenly spaced single pages and scale
            let mut hits = 0usize;
            let mut probed = 0usize;
            let step = npages / MINCORE_SAMPLE_PROBES;
            let mut byte = [0u8; 1];
            for i in 0..MINCORE_SAMPLE_PROBES {
                let addr = base + i * step * page;
                let rc = unsafe { sys::mincore(addr as *mut u8, 1, byte.as_mut_ptr()) };
                if rc != 0 {
                    continue;
                }
                probed += 1;
                if byte[0] & 1 != 0 {
                    hits += 1;
                }
            }
            if probed > 0 {
                resident += (len as f64 * hits as f64 / probed as f64) as u64;
            }
        }
    }
    counters().note_resident_sampled(resident);
    resident
}

/// Fallback targets hold mapped bytes on the heap — always resident.
#[cfg(not(all(unix, target_pointer_width = "64")))]
pub fn sample_residency() -> u64 {
    let resident = counters().mapped_code_bytes();
    counters().note_resident_sampled(resident);
    resident
}

/// An immutable, shareable memory map of one whole file.
///
/// On 64-bit unix this is a real `mmap(PROT_READ, MAP_SHARED)` — pages
/// live in the page cache and are shared with every other process
/// mapping the same file. Elsewhere it degrades to an owned heap copy
/// with the same interface.
#[derive(Debug)]
pub struct Mmap {
    #[cfg(all(unix, target_pointer_width = "64"))]
    ptr: *const u8,
    #[cfg(all(unix, target_pointer_width = "64"))]
    len: usize,
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    data: Vec<u8>,
    /// Bytes this map has advised resident (WILLNEED) — subtracted from
    /// the global gauge when the map drops.
    advised_resident: AtomicUsize,
}

// The mapping is immutable (PROT_READ) for its whole lifetime, so
// sharing the raw pointer across threads is sound.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for Mmap {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only in its entirety.
    pub fn open(path: &Path) -> Result<Mmap> {
        let map = Self::open_inner(path)?;
        let c = counters();
        c.note_map_open(map.len());
        Ok(map)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn open_inner(path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(Error::CorruptIndex(format!("file length {len} overflows usize")));
        }
        let len = len as usize;
        if len == 0 {
            // mmap(len = 0) is EINVAL; an empty map needs no pages
            return Ok(Mmap {
                ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(),
                len: 0,
                advised_resident: AtomicUsize::new(0),
            });
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1
        if ptr as usize == usize::MAX {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        regions().lock().unwrap().push((ptr as usize, len));
        Ok(Mmap { ptr, len, advised_resident: AtomicUsize::new(0) })
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn open_inner(path: &Path) -> Result<Mmap> {
        // fallback target: no zero-copy, but the same lifecycle and
        // accounting so callers never need to special-case the host
        let data = std::fs::read(path)?;
        Ok(Mmap { data, advised_resident: AtomicUsize::new(0) })
    }

    pub fn len(&self) -> usize {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            self.len
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            self.data.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Advise the kernel about the residency of `[offset, offset+len)`:
    /// `resident = true` → WILLNEED (fault ahead), `false` → DONTNEED
    /// (drop clean pages now). Best-effort — a refusing kernel (e.g.
    /// QEMU user mode) only costs the hint. Returns whether a hint was
    /// actually issued, and keeps the global resident-bytes gauge in
    /// sync either way.
    pub fn advise_resident(&self, offset: usize, len: usize, resident: bool) -> bool {
        let end = offset.saturating_add(len).min(self.len());
        let offset = offset.min(self.len());
        if end <= offset {
            return false;
        }
        let span = end - offset;
        if resident {
            self.advised_resident.fetch_add(span, Ordering::Relaxed);
            counters().note_resident(span as i64);
        }
        self.advise_sys(offset, end, resident)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn advise_sys(&self, offset: usize, end: usize, resident: bool) -> bool {
        let start = offset & !(ADVISE_ALIGN - 1);
        let advice = if resident { sys::MADV_WILLNEED } else { sys::MADV_DONTNEED };
        let rc = unsafe { sys::madvise(self.ptr.add(start) as *mut u8, end - start, advice) };
        rc == 0
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn advise_sys(&self, _offset: usize, _end: usize, _resident: bool) -> bool {
        let _ = ADVISE_ALIGN;
        false
    }
}

impl Deref for Mmap {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        #[cfg(all(unix, target_pointer_width = "64"))]
        unsafe {
            std::slice::from_raw_parts(self.ptr, self.len)
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            &self.data
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        let c = counters();
        c.note_map_close(self.len(), self.advised_resident.load(Ordering::Relaxed));
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len > 0 {
            // deregister BEFORE munmap so a concurrent residency sample
            // never probes an address range that has been unmapped
            regions().lock().unwrap().retain(|&(base, _)| base != self.ptr as usize);
            unsafe {
                sys::munmap(self.ptr as *mut u8, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_file(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("armpq_mmap_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.bin");
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn maps_file_contents_exactly() {
        let bytes: Vec<u8> = (0..100_000u32).map(|i| (i * 7 % 251) as u8).collect();
        let path = tmp_file("exact", &bytes);
        let opens_before = counters().mmap_open_total();
        let map = Mmap::open(&path).unwrap();
        assert_eq!(map.len(), bytes.len());
        assert_eq!(&map[..], &bytes[..]);
        assert!(counters().mmap_open_total() > opens_before);
        // advice is best-effort but must never corrupt the mapping
        map.advise_resident(0, 4096, true);
        map.advise_resident(4096, map.len(), false);
        assert_eq!(&map[..], &bytes[..]);
        drop(map);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_maps_empty() {
        let path = tmp_file("empty", &[]);
        let map = Mmap::open(&path).unwrap();
        assert!(map.is_empty());
        assert_eq!(&map[..], &[] as &[u8]);
        assert!(!map.advise_resident(0, 10, true));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_errors() {
        let path = std::env::temp_dir().join("armpq_mmap_definitely_missing.bin");
        assert!(Mmap::open(&path).is_err());
    }

    /// `mincore` residency sampling: a freshly touched map reports some
    /// resident bytes, the gauge tracks the sample, and the sample never
    /// exceeds what this process has mapped. Dropping the map removes
    /// its region from the walk.
    #[test]
    fn residency_sampling_tracks_live_maps() {
        let bytes = vec![0x5Au8; 256 * 1024];
        let path = tmp_file("mincore", &bytes);
        let map = Mmap::open(&path).unwrap();
        // touch every page so the kernel must hold at least some of them
        let mut acc = 0u64;
        for i in (0..map.len()).step_by(4096) {
            acc += map[i] as u64;
        }
        assert!(acc > 0);
        let sampled = sample_residency();
        assert_eq!(counters().resident_sampled_bytes(), sampled);
        assert!(
            sampled <= counters().mapped_code_bytes(),
            "sampled {sampled} > mapped {}",
            counters().mapped_code_bytes()
        );
        drop(map);
        // other tests may hold maps concurrently; the invariant after
        // drop is only that sampling still succeeds and stays bounded
        let after = sample_residency();
        assert!(after <= counters().mapped_code_bytes());
        std::fs::remove_file(&path).unwrap();
    }
}
