//! Read-only whole-file memory map with RAII unmap and best-effort
//! residency advice.
//!
//! The crate is dependency-free, so on 64-bit unix targets the
//! `mmap`/`munmap`/`madvise` bindings are declared by hand — std already
//! links libc there, so they resolve without adding a crate. Every other
//! target gets a transparent fallback that reads the file into the heap
//! behind the same API (no zero-copy, but identical semantics).

use super::budget::counters;
use crate::{Error, Result};
use std::ops::Deref;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Advice alignment: `madvise` wants page-aligned addresses, and the
/// largest page size in common use (aarch64 64K-page kernels) divides
/// this, so rounding region starts down to a 64 KiB boundary is aligned
/// on every supported host without querying the page size.
const ADVISE_ALIGN: usize = 64 * 1024;

#[cfg(all(unix, target_pointer_width = "64"))]
mod sys {
    // Hand-declared libc bindings (see the module doc for why).
    extern "C" {
        pub fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        pub fn munmap(addr: *mut u8, len: usize) -> i32;
        pub fn madvise(addr: *mut u8, len: usize, advice: i32) -> i32;
    }
    pub const PROT_READ: i32 = 1;
    pub const MAP_SHARED: i32 = 1;
    // identical numeric values on linux and the BSD family (incl. macOS)
    pub const MADV_WILLNEED: i32 = 3;
    pub const MADV_DONTNEED: i32 = 4;
}

/// An immutable, shareable memory map of one whole file.
///
/// On 64-bit unix this is a real `mmap(PROT_READ, MAP_SHARED)` — pages
/// live in the page cache and are shared with every other process
/// mapping the same file. Elsewhere it degrades to an owned heap copy
/// with the same interface.
#[derive(Debug)]
pub struct Mmap {
    #[cfg(all(unix, target_pointer_width = "64"))]
    ptr: *const u8,
    #[cfg(all(unix, target_pointer_width = "64"))]
    len: usize,
    #[cfg(not(all(unix, target_pointer_width = "64")))]
    data: Vec<u8>,
    /// Bytes this map has advised resident (WILLNEED) — subtracted from
    /// the global gauge when the map drops.
    advised_resident: AtomicUsize,
}

// The mapping is immutable (PROT_READ) for its whole lifetime, so
// sharing the raw pointer across threads is sound.
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Send for Mmap {}
#[cfg(all(unix, target_pointer_width = "64"))]
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only in its entirety.
    pub fn open(path: &Path) -> Result<Mmap> {
        let map = Self::open_inner(path)?;
        let c = counters();
        c.note_map_open(map.len());
        Ok(map)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn open_inner(path: &Path) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        let file = std::fs::File::open(path)?;
        let len = file.metadata()?.len();
        if len > usize::MAX as u64 {
            return Err(Error::CorruptIndex(format!("file length {len} overflows usize")));
        }
        let len = len as usize;
        if len == 0 {
            // mmap(len = 0) is EINVAL; an empty map needs no pages
            return Ok(Mmap {
                ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(),
                len: 0,
                advised_resident: AtomicUsize::new(0),
            });
        }
        let ptr = unsafe {
            sys::mmap(
                std::ptr::null_mut(),
                len,
                sys::PROT_READ,
                sys::MAP_SHARED,
                file.as_raw_fd(),
                0,
            )
        };
        // MAP_FAILED is (void*)-1
        if ptr as usize == usize::MAX {
            return Err(Error::Io(std::io::Error::last_os_error()));
        }
        Ok(Mmap { ptr, len, advised_resident: AtomicUsize::new(0) })
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn open_inner(path: &Path) -> Result<Mmap> {
        // fallback target: no zero-copy, but the same lifecycle and
        // accounting so callers never need to special-case the host
        let data = std::fs::read(path)?;
        Ok(Mmap { data, advised_resident: AtomicUsize::new(0) })
    }

    pub fn len(&self) -> usize {
        #[cfg(all(unix, target_pointer_width = "64"))]
        {
            self.len
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            self.data.len()
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Advise the kernel about the residency of `[offset, offset+len)`:
    /// `resident = true` → WILLNEED (fault ahead), `false` → DONTNEED
    /// (drop clean pages now). Best-effort — a refusing kernel (e.g.
    /// QEMU user mode) only costs the hint. Returns whether a hint was
    /// actually issued, and keeps the global resident-bytes gauge in
    /// sync either way.
    pub fn advise_resident(&self, offset: usize, len: usize, resident: bool) -> bool {
        let end = offset.saturating_add(len).min(self.len());
        let offset = offset.min(self.len());
        if end <= offset {
            return false;
        }
        let span = end - offset;
        if resident {
            self.advised_resident.fetch_add(span, Ordering::Relaxed);
            counters().note_resident(span as i64);
        }
        self.advise_sys(offset, end, resident)
    }

    #[cfg(all(unix, target_pointer_width = "64"))]
    fn advise_sys(&self, offset: usize, end: usize, resident: bool) -> bool {
        let start = offset & !(ADVISE_ALIGN - 1);
        let advice = if resident { sys::MADV_WILLNEED } else { sys::MADV_DONTNEED };
        let rc = unsafe { sys::madvise(self.ptr.add(start) as *mut u8, end - start, advice) };
        rc == 0
    }

    #[cfg(not(all(unix, target_pointer_width = "64")))]
    fn advise_sys(&self, _offset: usize, _end: usize, _resident: bool) -> bool {
        let _ = ADVISE_ALIGN;
        false
    }
}

impl Deref for Mmap {
    type Target = [u8];
    fn deref(&self) -> &[u8] {
        #[cfg(all(unix, target_pointer_width = "64"))]
        unsafe {
            std::slice::from_raw_parts(self.ptr, self.len)
        }
        #[cfg(not(all(unix, target_pointer_width = "64")))]
        {
            &self.data
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        let c = counters();
        c.note_map_close(self.len(), self.advised_resident.load(Ordering::Relaxed));
        #[cfg(all(unix, target_pointer_width = "64"))]
        if self.len > 0 {
            unsafe {
                sys::munmap(self.ptr as *mut u8, self.len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_file(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("armpq_mmap_{}_{name}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("f.bin");
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn maps_file_contents_exactly() {
        let bytes: Vec<u8> = (0..100_000u32).map(|i| (i * 7 % 251) as u8).collect();
        let path = tmp_file("exact", &bytes);
        let opens_before = counters().mmap_open_total();
        let map = Mmap::open(&path).unwrap();
        assert_eq!(map.len(), bytes.len());
        assert_eq!(&map[..], &bytes[..]);
        assert!(counters().mmap_open_total() > opens_before);
        // advice is best-effort but must never corrupt the mapping
        map.advise_resident(0, 4096, true);
        map.advise_resident(4096, map.len(), false);
        assert_eq!(&map[..], &bytes[..]);
        drop(map);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_maps_empty() {
        let path = tmp_file("empty", &[]);
        let map = Mmap::open(&path).unwrap();
        assert!(map.is_empty());
        assert_eq!(&map[..], &[] as &[u8]);
        assert!(!map.advise_resident(0, 10, true));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_errors() {
        let path = std::env::temp_dir().join("armpq_mmap_definitely_missing.bin");
        assert!(Mmap::open(&path).is_err());
    }
}
