//! Hierarchical Navigable Small World graphs (Malkov & Yashunin, 2020).
//!
//! Substrate for the paper's §4/§5.2 pipeline: *"(1) using HNSW for coarse
//! quantization, and (2) using 4-bit PQ for distance estimation"*. The
//! graph indexes the `nlist` IVF representative vectors (μ₁…μ_nlist), so
//! coarse assignment of a query is a graph walk instead of a linear scan
//! over 30 000 centroids.
//!
//! Implementation follows the paper's Algorithm 1–5: exponentially
//! distributed level assignment, greedy descent on upper layers,
//! `ef`-bounded best-first search on layer 0, and the *heuristic* neighbor
//! selection rule (shrink by dominance, Algorithm 4) that keeps the graph
//! navigable.

use crate::util::l2_sq;
use crate::util::rng::Rng;
use crate::{Error, Result};
use std::collections::BinaryHeap;

/// HNSW construction/search parameters.
#[derive(Clone, Debug)]
pub struct HnswParams {
    /// Max out-degree per node on layers > 0 (layer 0 gets 2×).
    pub m: usize,
    /// Candidate-list width during construction.
    pub ef_construction: usize,
    pub seed: u64,
}

impl Default for HnswParams {
    fn default() -> Self {
        // M=32 matches the factory string "HNSW32" used in the evaluation.
        Self { m: 32, ef_construction: 64, seed: 2024 }
    }
}

/// Ordered float wrapper for heaps.
#[derive(PartialEq)]
struct Cand {
    d: f32,
    id: u32,
}
impl Eq for Cand {}
impl PartialOrd for Cand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Cand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // max-heap by distance
        self.d.partial_cmp(&other.d).unwrap_or(std::cmp::Ordering::Equal)
    }
}

/// Min-heap adapter.
struct MinCand(Cand);
impl PartialEq for MinCand {
    fn eq(&self, other: &Self) -> bool {
        self.0.d == other.0.d
    }
}
impl Eq for MinCand {}
impl PartialOrd for MinCand {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for MinCand {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        other.0.cmp(&self.0)
    }
}

/// One node's adjacency across its levels.
#[derive(Clone, Debug, Default)]
struct Node {
    /// `neighbors[l]` = out-edges on level `l` (0 ≤ l ≤ level).
    neighbors: Vec<Vec<u32>>,
}

/// An HNSW index over explicitly stored vectors.
#[derive(Debug)]
pub struct Hnsw {
    pub dim: usize,
    params: HnswParams,
    /// mult = 1 / ln(M) — level sampling temperature.
    mult: f64,
    vectors: Vec<f32>,
    nodes: Vec<Node>,
    entry: u32,
    max_level: usize,
    rng: Rng,
}

impl Hnsw {
    pub fn new(dim: usize, params: HnswParams) -> Self {
        let mult = 1.0 / (params.m as f64).ln();
        Self {
            dim,
            rng: Rng::new(params.seed),
            params,
            mult,
            vectors: Vec::new(),
            nodes: Vec::new(),
            entry: 0,
            max_level: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    #[inline]
    fn vec_of(&self, id: u32) -> &[f32] {
        &self.vectors[id as usize * self.dim..(id as usize + 1) * self.dim]
    }

    fn random_level(&mut self) -> usize {
        let u = self.rng.next_f64().max(f64::MIN_POSITIVE);
        ((-u.ln()) * self.mult).floor() as usize
    }

    /// Insert all rows of `data` (`n × dim`).
    pub fn add_batch(&mut self, data: &[f32]) -> Result<()> {
        if data.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: data.len() % self.dim });
        }
        for row in data.chunks(self.dim) {
            self.add_one(row);
        }
        Ok(())
    }

    /// Insert a single vector.
    pub fn add_one(&mut self, x: &[f32]) {
        debug_assert_eq!(x.len(), self.dim);
        let id = self.nodes.len() as u32;
        self.vectors.extend_from_slice(x);
        let level = self.random_level();
        self.nodes.push(Node { neighbors: vec![Vec::new(); level + 1] });

        if id == 0 {
            self.entry = 0;
            self.max_level = level;
            return;
        }

        let mut ep = self.entry;
        // greedy descent through layers above `level`
        let mut l = self.max_level;
        while l > level {
            ep = self.greedy_closest(x, ep, l);
            if l == 0 {
                break;
            }
            l -= 1;
        }
        // insert on layers min(level, max_level)..0
        let top = level.min(self.max_level);
        let mut eps = vec![ep];
        for lc in (0..=top).rev() {
            let cands = self.search_layer(x, &eps, self.params.ef_construction, lc);
            let max_deg = if lc == 0 { self.params.m * 2 } else { self.params.m };
            let selected = self.select_neighbors_heuristic(&cands, self.params.m);
            for &(_, nb) in &selected {
                self.link(id, nb, lc, max_deg);
                self.link(nb, id, lc, max_deg);
            }
            eps = cands.iter().map(|&(_, i)| i).collect();
            if eps.is_empty() {
                eps = vec![ep];
            }
        }
        if level > self.max_level {
            self.max_level = level;
            self.entry = id;
        }
    }

    /// Add a directed edge, shrinking with the heuristic when over degree.
    fn link(&mut self, from: u32, to: u32, level: usize, max_deg: usize) {
        if from == to {
            return;
        }
        let nbrs = &mut self.nodes[from as usize].neighbors[level];
        if nbrs.contains(&to) {
            return;
        }
        nbrs.push(to);
        if nbrs.len() > max_deg {
            // re-select among current neighbors by the dominance heuristic
            let base = self.vec_of(from).to_vec();
            let cand: Vec<(f32, u32)> = self.nodes[from as usize].neighbors[level]
                .iter()
                .map(|&nb| (l2_sq(&base, self.vec_of(nb)), nb))
                .collect();
            let kept = self.select_neighbors_heuristic(&cand, max_deg);
            self.nodes[from as usize].neighbors[level] = kept.iter().map(|&(_, i)| i).collect();
        }
    }

    /// Algorithm 4: keep candidates not dominated by an already-kept
    /// neighbor (`d(c, kept) < d(c, base)` → drop c).
    fn select_neighbors_heuristic(&self, cands: &[(f32, u32)], m: usize) -> Vec<(f32, u32)> {
        let mut sorted: Vec<(f32, u32)> = cands.to_vec();
        sorted.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let mut kept: Vec<(f32, u32)> = Vec::with_capacity(m);
        for &(d, c) in &sorted {
            if kept.len() >= m {
                break;
            }
            let cv = self.vec_of(c);
            let dominated = kept.iter().any(|&(_, k)| l2_sq(cv, self.vec_of(k)) < d);
            if !dominated {
                kept.push((d, c));
            }
        }
        // backfill with nearest dominated candidates if underfull
        if kept.len() < m {
            for &(d, c) in &sorted {
                if kept.len() >= m {
                    break;
                }
                if !kept.iter().any(|&(_, k)| k == c) {
                    kept.push((d, c));
                }
            }
        }
        kept
    }

    /// Greedy single-step descent to the local minimum on `level`.
    fn greedy_closest(&self, x: &[f32], mut ep: u32, level: usize) -> u32 {
        let mut best = l2_sq(x, self.vec_of(ep));
        loop {
            let mut improved = false;
            for &nb in &self.nodes[ep as usize].neighbors[level] {
                let d = l2_sq(x, self.vec_of(nb));
                if d < best {
                    best = d;
                    ep = nb;
                    improved = true;
                }
            }
            if !improved {
                return ep;
            }
        }
    }

    /// Algorithm 2: ef-bounded best-first search on one layer.
    /// Returns up to `ef` `(distance, id)` pairs, ascending.
    fn search_layer(&self, x: &[f32], eps: &[u32], ef: usize, level: usize) -> Vec<(f32, u32)> {
        let mut visited = vec![false; self.nodes.len()];
        let mut top: BinaryHeap<Cand> = BinaryHeap::new(); // max-heap of results
        let mut queue: BinaryHeap<MinCand> = BinaryHeap::new(); // min-heap frontier
        for &ep in eps {
            if visited[ep as usize] {
                continue;
            }
            visited[ep as usize] = true;
            let d = l2_sq(x, self.vec_of(ep));
            top.push(Cand { d, id: ep });
            queue.push(MinCand(Cand { d, id: ep }));
        }
        while let Some(MinCand(c)) = queue.pop() {
            let worst = top.peek().map(|w| w.d).unwrap_or(f32::INFINITY);
            if c.d > worst && top.len() >= ef {
                break;
            }
            for &nb in &self.nodes[c.id as usize].neighbors[level] {
                if visited[nb as usize] {
                    continue;
                }
                visited[nb as usize] = true;
                let d = l2_sq(x, self.vec_of(nb));
                let worst = top.peek().map(|w| w.d).unwrap_or(f32::INFINITY);
                if top.len() < ef || d < worst {
                    top.push(Cand { d, id: nb });
                    if top.len() > ef {
                        top.pop();
                    }
                    queue.push(MinCand(Cand { d, id: nb }));
                }
            }
        }
        let mut out: Vec<(f32, u32)> = top.into_iter().map(|c| (c.d, c.id)).collect();
        out.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        out
    }

    /// k-NN query: greedy descent to layer 0, then ef-bounded search.
    /// Returns `(distances, ids)` ascending, padded with `(INF, -1)`.
    pub fn search(&self, x: &[f32], k: usize, ef: usize) -> (Vec<f32>, Vec<i64>) {
        if self.is_empty() {
            return (vec![f32::INFINITY; k], vec![-1; k]);
        }
        let mut ep = self.entry;
        for l in (1..=self.max_level).rev() {
            ep = self.greedy_closest(x, ep, l);
        }
        let ef = ef.max(k);
        let found = self.search_layer(x, &[ep], ef, 0);
        let mut d: Vec<f32> = found.iter().take(k).map(|&(dd, _)| dd).collect();
        let mut ids: Vec<i64> = found.iter().take(k).map(|&(_, i)| i as i64).collect();
        while d.len() < k {
            d.push(f32::INFINITY);
            ids.push(-1);
        }
        (d, ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_data(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * dim).map(|_| rng.next_gaussian()).collect()
    }

    fn brute_knn(data: &[f32], dim: usize, q: &[f32], k: usize) -> Vec<i64> {
        let n = data.len() / dim;
        let mut d: Vec<(f32, i64)> =
            (0..n).map(|i| (l2_sq(q, &data[i * dim..(i + 1) * dim]), i as i64)).collect();
        d.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        d.truncate(k);
        d.into_iter().map(|(_, i)| i).collect()
    }

    #[test]
    fn exact_on_tiny_graph() {
        let dim = 4;
        let data = random_data(30, dim, 41);
        let mut h = Hnsw::new(dim, HnswParams::default());
        h.add_batch(&data).unwrap();
        for qi in 0..10 {
            let q = &data[qi * dim..(qi + 1) * dim];
            let (_d, ids) = h.search(q, 1, 32);
            assert_eq!(ids[0], qi as i64, "self-query must find itself");
        }
    }

    #[test]
    fn high_recall_on_medium_graph() {
        let dim = 16;
        let n = 2000;
        let data = random_data(n, dim, 42);
        let mut h = Hnsw::new(dim, HnswParams { m: 16, ef_construction: 64, seed: 7 });
        h.add_batch(&data).unwrap();
        let queries = random_data(100, dim, 43);
        let mut hits = 0;
        for q in queries.chunks(dim) {
            let gt = brute_knn(&data, dim, q, 1);
            let (_d, ids) = h.search(q, 1, 64);
            if ids[0] == gt[0] {
                hits += 1;
            }
        }
        let recall = hits as f64 / 100.0;
        assert!(recall >= 0.95, "recall@1 = {recall}");
    }

    #[test]
    fn recall_improves_with_ef() {
        let dim = 8;
        let n = 1500;
        let data = random_data(n, dim, 44);
        let mut h = Hnsw::new(dim, HnswParams { m: 8, ef_construction: 40, seed: 8 });
        h.add_batch(&data).unwrap();
        let queries = random_data(200, dim, 45);
        let mut recall = [0usize; 2];
        for q in queries.chunks(dim) {
            let gt = brute_knn(&data, dim, q, 1)[0];
            for (j, ef) in [2usize, 64].into_iter().enumerate() {
                let (_d, ids) = h.search(q, 1, ef);
                if ids[0] == gt {
                    recall[j] += 1;
                }
            }
        }
        assert!(recall[1] > recall[0], "ef=64 {} !> ef=2 {}", recall[1], recall[0]);
        assert!(recall[1] >= 190, "ef=64 recall {}", recall[1]);
    }

    #[test]
    fn distances_sorted_and_padded() {
        let dim = 4;
        let data = random_data(10, dim, 46);
        let mut h = Hnsw::new(dim, HnswParams::default());
        h.add_batch(&data).unwrap();
        let (d, ids) = h.search(&data[..dim], 20, 40);
        assert_eq!(d.len(), 20);
        for w in d.windows(2) {
            assert!(w[0] <= w[1]);
        }
        assert_eq!(ids.iter().filter(|&&i| i == -1).count(), 10);
    }

    #[test]
    fn empty_graph_search() {
        let h = Hnsw::new(4, HnswParams::default());
        let (d, ids) = h.search(&[0.0; 4], 3, 10);
        assert!(d.iter().all(|x| x.is_infinite()));
        assert!(ids.iter().all(|&i| i == -1));
    }

    #[test]
    fn degree_bounds_respected() {
        let dim = 8;
        let data = random_data(500, dim, 47);
        let p = HnswParams { m: 6, ef_construction: 30, seed: 9 };
        let mut h = Hnsw::new(dim, p.clone());
        h.add_batch(&data).unwrap();
        for node in &h.nodes {
            for (l, nbrs) in node.neighbors.iter().enumerate() {
                let cap = if l == 0 { p.m * 2 } else { p.m };
                assert!(nbrs.len() <= cap, "level {l} degree {} > {cap}", nbrs.len());
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let dim = 8;
        let data = random_data(300, dim, 48);
        let mk = || {
            let mut h = Hnsw::new(dim, HnswParams { m: 8, ef_construction: 32, seed: 10 });
            h.add_batch(&data).unwrap();
            h
        };
        let a = mk();
        let b = mk();
        let q = &data[..dim];
        assert_eq!(a.search(q, 5, 32).1, b.search(q, 5, 32).1);
    }

    #[test]
    fn duplicate_vectors_handled() {
        let dim = 4;
        let mut data = random_data(50, dim, 49);
        let dup = data[..dim].to_vec();
        for _ in 0..10 {
            data.extend_from_slice(&dup); // 10 duplicates of vector 0
        }
        let mut h = Hnsw::new(dim, HnswParams::default());
        h.add_batch(&data).unwrap();
        let (d, _ids) = h.search(&dup, 5, 32);
        assert!(d[..5].iter().all(|&x| x < 1e-9), "dups at distance 0: {d:?}");
    }
}
