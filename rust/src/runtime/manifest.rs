//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! rust runtime (names, files, input/output shapes and dtypes).

use crate::util::json::Json;
use crate::{Error, Result};
use std::path::{Path, PathBuf};

/// Shape + dtype of one tensor in an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorMeta {
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" or "i32".
    pub dtype: String,
}

impl TensorMeta {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<Self> {
        let name = v
            .get("name")
            .and_then(|x| x.as_str())
            .ok_or_else(|| Error::Runtime("tensor missing name".into()))?
            .to_string();
        let shape = v
            .get("shape")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::Runtime(format!("tensor {name} missing shape")))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| Error::Runtime("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = v
            .get("dtype")
            .and_then(|x| x.as_str())
            .ok_or_else(|| Error::Runtime(format!("tensor {name} missing dtype")))?
            .to_string();
        Ok(Self { name, shape, dtype })
    }
}

/// One exported module.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// e.g. "search_q8_n4096_d64_m16_k10".
    pub name: String,
    /// "search" | "fastscan" | "lut".
    pub kind: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorMeta>,
    pub outputs: Vec<TensorMeta>,
    /// Free-form numeric parameters (q, n, d, m, k…).
    pub params: std::collections::BTreeMap<String, usize>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub block_n: usize,
    pub block_q: usize,
    pub artifacts: Vec<ArtifactMeta>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| Error::Runtime(format!("read {}: {e} (run `make artifacts`)", path.display())))?;
        let v = Json::parse(&text).map_err(|e| Error::Runtime(format!("parse manifest: {e}")))?;
        let block_n = v.get("block_n").and_then(|x| x.as_usize()).unwrap_or(512);
        let block_q = v.get("block_q").and_then(|x| x.as_usize()).unwrap_or(8);
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .and_then(|x| x.as_arr())
            .ok_or_else(|| Error::Runtime("manifest missing artifacts".into()))?
        {
            let file = a
                .get("file")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::Runtime("artifact missing file".into()))?;
            let name = file.trim_end_matches(".hlo.txt").to_string();
            let kind = a
                .get("kind")
                .and_then(|x| x.as_str())
                .ok_or_else(|| Error::Runtime("artifact missing kind".into()))?
                .to_string();
            let inputs = a
                .get("inputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| Error::Runtime("artifact missing inputs".into()))?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(|x| x.as_arr())
                .ok_or_else(|| Error::Runtime("artifact missing outputs".into()))?
                .iter()
                .map(TensorMeta::from_json)
                .collect::<Result<Vec<_>>>()?;
            let mut params = std::collections::BTreeMap::new();
            for key in ["q", "n", "d", "m", "k"] {
                if let Some(x) = a.get(key).and_then(|x| x.as_usize()) {
                    params.insert(key.to_string(), x);
                }
            }
            artifacts.push(ArtifactMeta { name, kind, file: dir.join(file), inputs, outputs, params });
        }
        Ok(Self { dir: dir.to_path_buf(), block_n, block_q, artifacts })
    }

    /// Find an artifact by exact name.
    pub fn find(&self, name: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// Find by kind + parameter equality (e.g. kind="search", d=64).
    pub fn find_by(&self, kind: &str, params: &[(&str, usize)]) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| {
            a.kind == kind && params.iter().all(|(k, v)| a.params.get(*k) == Some(v))
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest_dir() -> PathBuf {
        let dir = std::env::temp_dir().join(format!("armpq_manifest_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let text = r#"{
          "format": "hlo-text", "block_n": 512, "block_q": 8,
          "artifacts": [
            {"kind": "search", "file": "search_q8_n4096_d64_m16_k10.hlo.txt",
             "q": 8, "n": 4096, "d": 64, "m": 16, "k": 10,
             "inputs": [
               {"name": "queries", "shape": [8, 64], "dtype": "f32"},
               {"name": "codes", "shape": [4096, 16], "dtype": "i32"},
               {"name": "codebooks", "shape": [16, 16, 4], "dtype": "f32"}],
             "outputs": [
               {"name": "distances", "shape": [8, 10], "dtype": "f32"},
               {"name": "labels", "shape": [8, 10], "dtype": "i32"}]}
          ]}"#;
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        dir
    }

    #[test]
    fn parses_manifest() {
        let dir = sample_manifest_dir();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.block_n, 512);
        assert_eq!(m.artifacts.len(), 1);
        let a = m.find("search_q8_n4096_d64_m16_k10").unwrap();
        assert_eq!(a.kind, "search");
        assert_eq!(a.inputs[1].shape, vec![4096, 16]);
        assert_eq!(a.inputs[1].numel(), 4096 * 16);
        assert_eq!(a.params["d"], 64);
        assert_eq!(a.outputs[0].dtype, "f32");
    }

    #[test]
    fn find_by_params() {
        let dir = sample_manifest_dir();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.find_by("search", &[("d", 64), ("m", 16)]).is_some());
        assert!(m.find_by("search", &[("d", 999)]).is_none());
        assert!(m.find_by("lut", &[]).is_none());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
