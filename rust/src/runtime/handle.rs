//! Thread-confined PJRT executor.
//!
//! The `xla` crate's client/executable types are `Rc`-based and must stay
//! on one thread. [`EngineHandle`] owns a dedicated executor thread that
//! hosts the [`super::Engine`]; other threads (the batcher workers, the
//! TCP handlers) submit jobs over a channel. This mirrors how serving
//! systems pin one executor per accelerator stream.

use super::engine::{Engine, Tensor};
use super::manifest::Manifest;
use crate::{Error, Result};
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, SyncSender};

enum Job {
    Execute { artifact: String, inputs: Vec<Tensor>, reply: SyncSender<Result<Vec<Tensor>>> },
    /// Pre-compile an artifact (warm the cache).
    Warm { artifact: String, reply: SyncSender<Result<()>> },
}

/// Sendable handle to a thread-confined [`Engine`].
pub struct EngineHandle {
    tx: SyncSender<Job>,
    /// Manifest parsed on the caller side (it is plain data).
    pub manifest: Manifest,
    _thread: std::thread::JoinHandle<()>,
}

impl EngineHandle {
    /// Spawn the executor thread and load the engine on it.
    pub fn spawn(artifacts_dir: PathBuf) -> Result<EngineHandle> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let (tx, rx) = sync_channel::<Job>(256);
        let (ready_tx, ready_rx) = sync_channel::<Result<()>>(1);
        let thread = std::thread::spawn(move || {
            let engine = match Engine::load(&artifacts_dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(job) = rx.recv() {
                match job {
                    Job::Execute { artifact, inputs, reply } => {
                        let result = engine
                            .executable(&artifact)
                            .and_then(|exe| exe.execute(&inputs));
                        let _ = reply.send(result);
                    }
                    Job::Warm { artifact, reply } => {
                        let _ = reply.send(engine.executable(&artifact).map(|_| ()));
                    }
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("executor thread died during init".into()))??;
        Ok(EngineHandle { tx, manifest, _thread: thread })
    }

    /// Execute an artifact by name (blocks until the executor replies).
    pub fn execute(&self, artifact: &str, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(Job::Execute { artifact: artifact.to_string(), inputs, reply: reply_tx })
            .map_err(|_| Error::Runtime("executor thread gone".into()))?;
        reply_rx.recv().map_err(|_| Error::Runtime("executor dropped the job".into()))?
    }

    /// Compile an artifact ahead of the first query.
    pub fn warm(&self, artifact: &str) -> Result<()> {
        let (reply_tx, reply_rx) = sync_channel(1);
        self.tx
            .send(Job::Warm { artifact: artifact.to_string(), reply: reply_tx })
            .map_err(|_| Error::Runtime("executor thread gone".into()))?;
        reply_rx.recv().map_err(|_| Error::Runtime("executor dropped the job".into()))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("artifacts missing; run `make artifacts` first");
            None
        }
    }

    #[test]
    fn handle_executes_from_other_threads() {
        let Some(dir) = artifacts_dir() else { return };
        let h = std::sync::Arc::new(EngineHandle::spawn(dir).unwrap());
        let name = h.manifest.artifacts.iter().find(|a| a.kind == "fastscan").unwrap();
        let (n, m, q) = (name.params["n"], name.params["m"], name.params["q"]);
        let name = name.name.clone();
        h.warm(&name).unwrap();
        let mut threads = Vec::new();
        for t in 0..3 {
            let h = h.clone();
            let name = name.clone();
            threads.push(std::thread::spawn(move || {
                let codes = Tensor::I32(vec![t as i32 % 16; n * m], vec![n, m]);
                let luts = Tensor::I32(vec![1; q * m * 16], vec![q, m * 16]);
                let out = h.execute(&name, vec![codes, luts]).unwrap();
                assert_eq!(out[0].shape(), &[n, q]);
                assert!(out[0].as_i32().unwrap().iter().all(|&x| x == m as i32));
            }));
        }
        for t in threads {
            t.join().unwrap();
        }
    }

    #[test]
    fn unknown_artifact_errors() {
        let Some(dir) = artifacts_dir() else { return };
        let h = EngineHandle::spawn(dir).unwrap();
        assert!(h.warm("nope").is_err());
        assert!(h.execute("nope", vec![]).is_err());
    }
}
