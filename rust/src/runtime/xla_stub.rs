//! Build-time stub for the `xla` crate (PJRT bindings).
//!
//! The vendored crate set does not include `xla` (it links the XLA C++
//! runtime, which is unavailable in this build environment), so this module
//! reproduces the exact API surface [`super::engine`] consumes. Every entry
//! point that would reach PJRT fails at *runtime* with a clear
//! "PJRT runtime unavailable" error; nothing fails at build time.
//!
//! [`super::engine::Engine::load`] calls [`PjRtClient::cpu`] first, so a
//! process without real PJRT support can never obtain an executable — the
//! remaining methods exist purely so the engine typechecks, and are
//! unreachable in practice. Swapping this module for the real crate
//! (`use xla;` instead of `use super::xla_stub as xla;`) restores the
//! original three-layer pipeline unchanged.

use std::fmt;

/// Mirror of `xla::Error`.
#[derive(Debug)]
pub struct Error(pub String);

impl Error {
    fn unavailable() -> Error {
        Error(
            "PJRT runtime unavailable: armpq was built without the xla crate \
             (see runtime::xla_stub)"
                .into(),
        )
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

impl From<Error> for crate::Error {
    fn from(e: Error) -> Self {
        crate::Error::Runtime(format!("{e}"))
    }
}

/// Element types a [`Literal`] can hold (subset the engine uses).
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for i32 {}

/// Mirror of `xla::ArrayShape`.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Mirror of `xla::Literal` — a host tensor handle.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_v: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape, Error> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable())
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable())
    }
}

/// Mirror of `xla::HloModuleProto`.
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable())
    }
}

/// Mirror of `xla::XlaComputation`.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Mirror of `xla::PjRtBuffer` (device buffer handle).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable())
    }
}

/// Mirror of `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable())
    }
}

/// Mirror of `xla::PjRtClient`.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real crate constructs a CPU PJRT client here; the stub reports
    /// the runtime as unavailable, which [`super::engine::Engine::load`]
    /// surfaces to callers as a normal [`crate::Error::Runtime`].
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "unavailable".into()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT runtime unavailable"), "{err}");
    }

    #[test]
    fn error_converts_to_crate_runtime_error() {
        let e: crate::Error = Error::unavailable().into();
        assert!(matches!(e, crate::Error::Runtime(_)));
        assert!(e.to_string().contains("runtime error"));
    }
}
