//! PJRT execution engine: compile-once, execute-many.
//!
//! Wraps `xla::PjRtClient` (CPU) exactly as /opt/xla-example/load_hlo does:
//! `HloModuleProto::from_text_file → XlaComputation::from_proto →
//! client.compile`, with an executable cache so each artifact is compiled
//! once per process. All artifacts are lowered with `return_tuple=True`, so
//! results are unpacked from a single tuple literal.
//!
//! In this build the `xla` crate is replaced by [`super::xla_stub`] (the
//! C++ XLA runtime is not available here): [`Engine::load`] returns a
//! "PJRT runtime unavailable" error and every artifact-dependent test
//! skips. The engine code itself is unchanged and works against the real
//! crate by swapping the `use … as xla` import.

use super::manifest::{ArtifactMeta, Manifest};
use super::xla_stub as xla;
use crate::{Error, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// A host tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    F32(Vec<f32>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl Tensor {
    pub fn shape(&self) -> &[usize] {
        match self {
            Tensor::F32(_, s) | Tensor::I32(_, s) => s,
        }
    }

    pub fn dtype(&self) -> &'static str {
        match self {
            Tensor::F32(..) => "f32",
            Tensor::I32(..) => "i32",
        }
    }

    pub fn numel(&self) -> usize {
        self.shape().iter().product()
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v, _) => Ok(v),
            _ => Err(Error::Runtime("expected f32 tensor".into())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v, _) => Ok(v),
            _ => Err(Error::Runtime("expected i32 tensor".into())),
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            Tensor::F32(v, _) => xla::Literal::vec1(v),
            Tensor::I32(v, _) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(&dims)?)
    }

    fn from_literal(lit: &xla::Literal, meta_dtype: &str) -> Result<Tensor> {
        let shape: Vec<usize> = lit
            .array_shape()
            .map_err(|e| Error::Runtime(format!("output shape: {e}")))?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect();
        match meta_dtype {
            "f32" => Ok(Tensor::F32(lit.to_vec::<f32>()?, shape)),
            "i32" => Ok(Tensor::I32(lit.to_vec::<i32>()?, shape)),
            other => Err(Error::Runtime(format!("unsupported dtype {other}"))),
        }
    }
}

/// One compiled artifact.
pub struct Executable {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable {
    /// Execute with shape-checked inputs; returns one tensor per manifest
    /// output.
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            )));
        }
        for (t, m) in inputs.iter().zip(&self.meta.inputs) {
            if t.shape() != m.shape.as_slice() || t.dtype() != m.dtype {
                return Err(Error::Runtime(format!(
                    "{}: input {} expects {:?} {}, got {:?} {}",
                    self.meta.name,
                    m.name,
                    m.shape,
                    m.dtype,
                    t.shape(),
                    t.dtype()
                )));
            }
        }
        let literals: Vec<xla::Literal> =
            inputs.iter().map(|t| t.to_literal()).collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        // return_tuple=True → single tuple literal with one element per output
        let parts = result.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            return Err(Error::Runtime(format!(
                "{}: expected {} outputs, got {}",
                self.meta.name,
                self.meta.outputs.len(),
                parts.len()
            )));
        }
        parts
            .iter()
            .zip(&self.meta.outputs)
            .map(|(lit, m)| Tensor::from_literal(lit, &m.dtype))
            .collect()
    }
}

/// The engine: PJRT client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
}

impl Engine {
    /// Create a CPU engine over an artifacts directory.
    pub fn load(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling on first use) the executable for `name`.
    pub fn executable(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .find(name)
            .ok_or_else(|| Error::Runtime(format!("unknown artifact {name}")))?
            .clone();
        let proto = xla::HloModuleProto::from_text_file(
            meta.file
                .to_str()
                .ok_or_else(|| Error::Runtime("non-utf8 artifact path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let arc = Arc::new(Executable { meta, exe });
        self.cache.lock().unwrap().insert(name.to_string(), arc.clone());
        Ok(arc)
    }

    /// Names of all artifacts of a given kind.
    pub fn names_of_kind(&self, kind: &str) -> Vec<String> {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == kind)
            .map(|a| a.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<std::path::PathBuf> {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(dir)
        } else {
            eprintln!("artifacts missing; run `make artifacts` first");
            None
        }
    }

    #[test]
    fn tensor_accessors() {
        let t = Tensor::F32(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.numel(), 4);
        assert_eq!(t.dtype(), "f32");
        assert!(t.as_f32().is_ok());
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn engine_loads_and_runs_fastscan_artifact() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        assert_eq!(engine.platform(), "cpu");
        let names = engine.names_of_kind("fastscan");
        assert!(!names.is_empty());
        let exe = engine.executable(&names[0]).unwrap();
        let n = exe.meta.params["n"];
        let m = exe.meta.params["m"];
        let q = exe.meta.params["q"];

        // codes all 3; LUT entry 3 of every row = m index + 1
        let codes = Tensor::I32(vec![3; n * m], vec![n, m]);
        let mut luts = vec![0i32; q * m * 16];
        for qi in 0..q {
            for mi in 0..m {
                luts[qi * m * 16 + mi * 16 + 3] = (mi + 1) as i32;
            }
        }
        let luts = Tensor::I32(luts, vec![q, m * 16]);
        let out = exe.execute(&[codes, luts]).unwrap();
        assert_eq!(out.len(), 1);
        let acc = out[0].as_i32().unwrap();
        let expect: i32 = (1..=m as i32).sum();
        assert_eq!(out[0].shape(), &[n, q]);
        assert!(acc.iter().all(|&x| x == expect), "acc[0]={} expect={expect}", acc[0]);
    }

    #[test]
    fn engine_shape_checks_inputs() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        let names = engine.names_of_kind("fastscan");
        let exe = engine.executable(&names[0]).unwrap();
        let bad = Tensor::I32(vec![0; 8], vec![8]);
        assert!(exe.execute(&[bad.clone(), bad]).is_err());
    }

    #[test]
    fn executable_cache_returns_same_instance() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        let names = engine.names_of_kind("lut");
        let a = engine.executable(&names[0]).unwrap();
        let b = engine.executable(&names[0]).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn search_artifact_end_to_end_vs_rust_pipeline() {
        // The exported L2 pipeline must agree with the rust fastscan
        // implementation on the same inputs (quantized scan, no rerank).
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::load(&dir).unwrap();
        let Some(meta) = engine.manifest.find_by("search", &[("d", 64)]).map(|m| m.name.clone())
        else {
            return;
        };
        let exe = engine.executable(&meta).unwrap();
        let (q, n, d, m) = (
            exe.meta.params["q"],
            exe.meta.params["n"],
            exe.meta.params["d"],
            exe.meta.params["m"],
        );
        let dsub = d / m;
        let mut rng = crate::util::rng::Rng::new(271);
        let queries: Vec<f32> = (0..q * d).map(|_| rng.next_gaussian()).collect();
        let codebooks: Vec<f32> = (0..m * 16 * dsub).map(|_| rng.next_gaussian()).collect();
        let codes: Vec<i32> = (0..n * m).map(|_| (rng.next_u32() % 16) as i32).collect();

        let out = exe
            .execute(&[
                Tensor::F32(queries.clone(), vec![q, d]),
                Tensor::I32(codes.clone(), vec![n, m]),
                Tensor::F32(codebooks.clone(), vec![m, 16, dsub]),
            ])
            .unwrap();
        let k = exe.meta.params["k"];
        assert_eq!(out[0].shape(), &[q, k]);
        let labels = out[1].as_i32().unwrap();
        let dists = out[0].as_f32().unwrap();

        // rust-side oracle: same quantized pipeline via pq modules
        use crate::pq::fastscan::{fastscan_distances_all, KernelLuts};
        use crate::pq::{CodeWidth, PackedCodes, QuantizedLuts};
        let codes_u8: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
        let packed = PackedCodes::pack(&codes_u8, m, CodeWidth::W4).unwrap();
        for qi in 0..q.min(3) {
            // build f32 luts for query qi
            let qrow = &queries[qi * d..(qi + 1) * d];
            let mut luts = vec![0.0f32; m * 16];
            for mi in 0..m {
                for kk in 0..16 {
                    let c = &codebooks[(mi * 16 + kk) * dsub..(mi * 16 + kk + 1) * dsub];
                    luts[mi * 16 + kk] =
                        crate::util::l2_sq(&qrow[mi * dsub..(mi + 1) * dsub], c);
                }
            }
            let qluts = QuantizedLuts::from_f32(&luts, m, 16);
            let kluts = KernelLuts::build(&qluts, packed.lut_rows);
            let all = fastscan_distances_all(&packed, &kluts, crate::simd::Backend::Portable);
            // top-1 from the artifact must match the rust argmin (decoded)
            let best = all.iter().enumerate().min_by_key(|&(_, &v)| v).unwrap();
            assert_eq!(labels[qi * k] as usize, best.0, "query {qi} label");
            let decoded = qluts.decode(*best.1);
            let got = dists[qi * k];
            assert!(
                (decoded - got).abs() < 1e-2 * (1.0 + decoded.abs()),
                "query {qi}: rust {decoded} vs artifact {got}"
            );
        }
    }
}
