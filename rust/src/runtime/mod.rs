//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts and execute
//! them from the rust hot path (python never runs at request time).
//!
//! Flow (see /opt/xla-example/load_hlo): `artifacts/manifest.json` lists the
//! exported modules; each `*.hlo.txt` is parsed with
//! `HloModuleProto::from_text_file`, compiled once on the PJRT CPU client,
//! and cached as an executable keyed by artifact name. Inputs/outputs are
//! shape-checked against the manifest.

pub mod engine;
pub mod handle;
pub mod manifest;
pub mod xla_stub;

pub use engine::{Engine, Executable, Tensor};
pub use handle::EngineHandle;
pub use manifest::{ArtifactMeta, Manifest, TensorMeta};
