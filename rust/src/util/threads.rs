//! Data-parallel helpers over the persistent worker pool (rayon is not in
//! the vendored crate set) — the thread substrate of the plan/execute
//! query layer ([`crate::exec`]).
//!
//! # Ownership: pool vs scratch vs plan
//!
//! Since the persistent-runtime PR, nothing here spawns threads on the
//! query path. The split is:
//!
//! * **The worker pool** ([`crate::exec::pool::WorkerPool`]) owns the
//!   threads. Workers are spawned once per [`crate::exec::QueryExecutor`]
//!   (the process-global executor backs the free functions below),
//!   optionally pinned to cores, and fed by per-worker injector queues
//!   with work-stealing. Submitting a parallel call posts revocable helper
//!   jobs and always participates inline, so a busy pool degrades to
//!   serial execution instead of queueing behind itself.
//! * **Per-thread scratch** (a [`crate::exec::ScanScratch`] checked out of
//!   the executor's [`crate::exec::ScratchPool`]): LUT buffers,
//!   reservoirs, re-rank staging — mutable, owned by exactly one
//!   participant at a time, grown but never shrunk. The `init` hook of
//!   [`parallel_map_init`] still runs once per participant, so arenas stay
//!   bounded by the thread budget.
//! * **Per-request** state (a [`crate::exec::QueryPlan`]): read-only,
//!   shared by every participant by borrow — the pool's claim/revoke
//!   protocol (see [`crate::exec::pool`]) is what lets persistent threads
//!   borrow from the submitting stack frame safely.
//!
//! The scoped per-call implementations survive as [`scoped_chunks`] /
//! [`scoped_map_init`]: they are the differential baseline the pool is
//! bench-compared and bit-identity-tested against, and the fallback used
//! by executors built with `QueryExecutor::new_scoped`.
//!
//! Determinism contract (unchanged): these helpers never change *what* is
//! computed, only *where*. Per-iteration work must be a pure function of
//! the iteration index (plus scratch used strictly as workspace), writing
//! to disjoint per-index output slots — so chunk assignment, claim order
//! and steals cannot alter a single byte of the result.

use crate::exec::pool::WorkerPool;

/// Number of worker threads to use by default (`ARMPQ_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ARMPQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads`
/// contiguous chunks, submitted to the global executor's worker pool.
/// `f` must be `Sync` (shared immutable state); use interior outputs via
/// disjoint slices or per-chunk results.
///
/// The chunk decomposition is identical to the scoped-spawn era
/// (`chunk = ceil(n / threads)`), only the execution substrate changed —
/// the same `(start, end)` invocations occur either way.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    let nchunks = n.div_ceil(chunk);
    match crate::exec::QueryExecutor::global().worker_pool() {
        Some(pool) => {
            pool.run_units(nchunks, threads, || (), |c, _| {
                let start = c * chunk;
                let end = ((c + 1) * chunk).min(n);
                f(start, end);
            });
        }
        // the global executor is always pool-backed; this arm keeps the
        // match total if that ever changes
        None => scoped_chunks(n, threads, f),
    }
}

/// Map `f` over `[0, n)` in parallel on the global worker pool, collecting
/// results in index order.
///
/// Results are written through disjoint per-index `MaybeUninit` slots, so
/// `T` needs neither `Default` nor `Clone` — nothing is pre-filled and
/// overwritten.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_init(n, threads, || (), |i, _: &mut ()| f(i))
}

/// [`parallel_map`] with per-participant worker state: each participant
/// that claims at least one unit calls `init()` once and threads the state
/// through every unit it claims — the hook the query executor uses to
/// check one scratch arena out of the pool per worker instead of per
/// iteration.
///
/// Results land in index order. If `f` panics, the panic propagates after
/// the pool settles; initialized results of other slots are leaked (never
/// double-dropped or read uninitialized).
pub fn parallel_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(i, &mut state)).collect();
    }
    match crate::exec::QueryExecutor::global().worker_pool() {
        Some(pool) => pool_map_placed(pool, n, threads, |_| 0, init, f).0,
        None => scoped_map_init(n, threads, init, f),
    }
}

/// The shared pooled-map core: run `f` over `[0, n)` on `pool` with unit
/// claiming (work-stealing granularity = one index), `node_of` placement
/// hints, and ordered `MaybeUninit` output slots. Returns the results plus
/// how many participants actually executed units (the executor feeds this
/// into `QueryStats.threads_used`).
pub(crate) fn pool_map_placed<T, S, P, I, F>(
    pool: &WorkerPool,
    n: usize,
    parallelism: usize,
    node_of: P,
    init: I,
    f: F,
) -> (Vec<T>, usize)
where
    T: Send,
    P: Fn(usize) -> usize,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    if n == 0 {
        return (Vec::new(), 0);
    }
    let mut out: Vec<std::mem::MaybeUninit<T>> = Vec::with_capacity(n);
    out.resize_with(n, std::mem::MaybeUninit::uninit);
    let participants;
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        participants = pool.run_units_placed(n, parallelism, node_of, init, |i, state| {
            let p = out_ptr;
            let value = f(i, state);
            // SAFETY: the pool claims each unit index exactly once; each
            // slot is written exactly once by exactly one participant.
            unsafe {
                (*p.0.add(i)).write(value);
            }
        });
    }
    // SAFETY: run_units_placed covers [0, n) exactly once, so every slot
    // is initialized; Vec<MaybeUninit<T>> and Vec<T> share one layout.
    let out = unsafe {
        let mut out = std::mem::ManuallyDrop::new(out);
        Vec::from_raw_parts(out.as_mut_ptr() as *mut T, out.len(), out.capacity())
    };
    (out, participants)
}

/// The pre-pool scoped implementation of [`parallel_chunks`]: spawns
/// `std::thread::scope` threads per call with a static chunk assignment.
/// Kept as the differential baseline (`threads_` bit-identity tests, the
/// scoped-vs-pool bench arm) and as the substrate for
/// `QueryExecutor::new_scoped`.
pub fn scoped_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            let fref = &f;
            scope.spawn(move || fref(start, end));
        }
    });
}

/// The pre-pool scoped implementation of [`parallel_map_init`]: per-call
/// spawned threads, one `init()` per static chunk. Same determinism
/// contract and output semantics as the pooled path — the `threads_`
/// tests assert the two produce identical bytes.
pub fn scoped_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(i, &mut state)).collect();
    }
    let mut out: Vec<std::mem::MaybeUninit<T>> = Vec::with_capacity(n);
    out.resize_with(n, std::mem::MaybeUninit::uninit);
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        scoped_chunks(n, threads, |start, end| {
            let p = out_ptr;
            let mut state = init();
            for i in start..end {
                let value = f(i, &mut state);
                // SAFETY: chunks are disjoint index ranges; each slot is
                // written exactly once by exactly one thread.
                unsafe {
                    (*p.0.add(i)).write(value);
                }
            }
        });
    }
    // SAFETY: scoped_chunks covers [0, n) exactly once, so every slot is
    // initialized; Vec<MaybeUninit<T>> and Vec<T> share one layout.
    unsafe {
        let mut out = std::mem::ManuallyDrop::new(out);
        Vec::from_raw_parts(out.as_mut_ptr() as *mut T, out.len(), out.capacity())
    }
}

/// Pointer wrapper asserting cross-thread sendability for disjoint writes.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let counter = AtomicUsize::new(0);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(1000, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
                counter.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 3, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    /// Result types need neither `Default` nor `Clone`.
    #[test]
    fn map_without_default_or_clone() {
        struct Opaque(usize);
        let v = parallel_map(64, 4, Opaque);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(x.0, i);
        }
        // and with heap-owning results (drops must be exact, no leaks of
        // *initialized* slots on the happy path)
        let v = parallel_map(17, 4, |i| vec![i; i + 1]);
        assert_eq!(v[16], vec![16; 17]);
    }

    #[test]
    fn map_init_state_per_chunk() {
        // each participant gets exactly one init() call
        let inits = AtomicUsize::new(0);
        let v = parallel_map_init(
            100,
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                0usize
            },
            |i, seen| {
                *seen += 1;
                (i, *seen)
            },
        );
        assert!(inits.load(Ordering::SeqCst) <= 4);
        // within a participant the state accumulates, and indexes stay ordered
        for (i, &(idx, seen)) in v.iter().enumerate() {
            assert_eq!(idx, i);
            assert!(seen >= 1);
        }
    }

    #[test]
    fn zero_items() {
        parallel_chunks(0, 4, |_, _| panic!("must not run with n=0 range"));
        scoped_chunks(0, 4, |_, _| panic!("must not run with n=0 range"));
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
        let v: Vec<usize> =
            parallel_map_init(0, 4, || panic!("no init for n=0"), |i, _: &mut ()| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_path() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_items() {
        let v = parallel_map(3, 16, |i| i);
        assert_eq!(v, vec![0, 1, 2]);
    }

    /// The tentpole's core differential: the pooled helpers and the scoped
    /// baselines return identical bytes at every thread count.
    #[test]
    fn threads_pool_matches_scoped_bit_identical() {
        let work = |i: usize| (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (i as u64) << 7;
        for &t in &[1usize, 2, 3, 4, 8] {
            let pooled = parallel_map(257, t, work);
            let scoped = scoped_map_init(257, t, || (), |i, _: &mut ()| work(i));
            assert_eq!(pooled, scoped, "divergence at threads={t}");
        }
    }

    /// Same check for the chunked form: identical (start, end) coverage.
    #[test]
    fn threads_pool_chunks_match_scoped_coverage() {
        for &t in &[2usize, 4, 7] {
            let n = 101;
            let pooled: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            parallel_chunks(n, t, |s, e| {
                for i in s..e {
                    pooled[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            let scoped: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
            scoped_chunks(n, t, |s, e| {
                for i in s..e {
                    scoped[i].fetch_add(1, Ordering::SeqCst);
                }
            });
            for i in 0..n {
                assert_eq!(
                    pooled[i].load(Ordering::SeqCst),
                    scoped[i].load(Ordering::SeqCst)
                );
                assert_eq!(pooled[i].load(Ordering::SeqCst), 1);
            }
        }
    }
}
