//! Scoped data-parallel helpers built on `std::thread` (rayon is not in the
//! vendored crate set).
//!
//! `parallel_chunks` splits an index range into contiguous chunks and runs a
//! worker per chunk with `std::thread::scope`; on a single-core box it
//! degrades gracefully to a serial loop.

/// Number of worker threads to use by default (`ARMPQ_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ARMPQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads`
/// contiguous chunks. `f` must be `Sync` (shared immutable state); use
/// interior outputs via disjoint slices or per-chunk results.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            let fref = &f;
            scope.spawn(move || fref(start, end));
        }
    });
}

/// Map `f` over `[0, n)` in parallel, collecting results in index order.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_chunks(n, threads, |start, end| {
            // SAFETY: chunks are disjoint index ranges; each element is
            // written exactly once by exactly one thread.
            let p = out_ptr;
            for i in start..end {
                unsafe {
                    *p.0.add(i) = f(i);
                }
            }
        });
    }
    out
}

/// Pointer wrapper asserting cross-thread sendability for disjoint writes.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let counter = AtomicUsize::new(0);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(1000, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
                counter.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 3, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    #[test]
    fn zero_items() {
        parallel_chunks(0, 4, |_, _| panic!("must not run with n=0 range"));
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_path() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_items() {
        let v = parallel_map(3, 16, |i| i);
        assert_eq!(v, vec![0, 1, 2]);
    }
}
