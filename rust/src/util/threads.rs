//! Scoped data-parallel helpers built on `std::thread` (rayon is not in the
//! vendored crate set) — the thread substrate of the plan/execute query
//! layer ([`crate::exec`]).
//!
//! # Role in the plan/execute model
//!
//! Query execution splits state three ways:
//!
//! * **Per-request** state (a [`crate::exec::QueryPlan`]): resolved
//!   parameters, the compiled filter masks, the precomputed-LUT recipe.
//!   Built once per `query` call, shared *read-only* by every worker.
//! * **Per-thread scratch** (a [`crate::exec::ScanScratch`] checked out of
//!   the executor's pool): LUT buffers, reservoirs, re-rank staging —
//!   mutable, owned by exactly one worker at a time, grown but never
//!   shrunk, so the steady-state scan path allocates nothing.
//! * **Per-slot output**: each parallel iteration writes its result into
//!   its own disjoint slot ([`parallel_map_init`] hands every chunk a raw
//!   pointer range that no other chunk touches), so no locks and no
//!   `T: Default` dummy values are needed.
//!
//! Workers are `std::thread::scope` threads spawned per call: borrows of
//! the sealed index and the plan flow into the workers without `'static`
//! bounds or reference counting, and on a single-core box (or with
//! `ARMPQ_THREADS=1`) everything degrades to a plain serial loop.
//!
//! Determinism contract: these helpers never change *what* is computed,
//! only *where*. Callers must keep per-iteration work a pure function of
//! the iteration index (plus scratch used strictly as workspace); the
//! executor layer builds its bit-identical-across-thread-counts guarantee
//! on top of that.

/// Number of worker threads to use by default (`ARMPQ_THREADS` overrides).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("ARMPQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads`
/// contiguous chunks. `f` must be `Sync` (shared immutable state); use
/// interior outputs via disjoint slices or per-chunk results.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        f(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                continue;
            }
            let fref = &f;
            scope.spawn(move || fref(start, end));
        }
    });
}

/// Map `f` over `[0, n)` in parallel, collecting results in index order.
///
/// Results are written through per-chunk disjoint `MaybeUninit` slots, so
/// `T` needs no `Default`/`Clone` — nothing is pre-filled and overwritten.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    parallel_map_init(n, threads, || (), |i, _: &mut ()| f(i))
}

/// [`parallel_map`] with per-chunk worker state: each chunk calls `init()`
/// once and threads the state through its iterations — the hook the query
/// executor uses to check one scratch arena out of the pool per worker
/// instead of per iteration.
///
/// Results land in index order. If `f` panics, the panic propagates after
/// all workers join; initialized results of other slots are leaked (never
/// double-dropped or read uninitialized).
pub fn parallel_map_init<T, S, I, F>(n: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(usize, &mut S) -> T + Sync,
{
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.max(1).min(n);
    if threads <= 1 {
        let mut state = init();
        return (0..n).map(|i| f(i, &mut state)).collect();
    }
    let mut out: Vec<std::mem::MaybeUninit<T>> = Vec::with_capacity(n);
    out.resize_with(n, std::mem::MaybeUninit::uninit);
    {
        let out_ptr = SendPtr(out.as_mut_ptr());
        parallel_chunks(n, threads, |start, end| {
            let p = out_ptr;
            let mut state = init();
            for i in start..end {
                let value = f(i, &mut state);
                // SAFETY: chunks are disjoint index ranges; each slot is
                // written exactly once by exactly one thread.
                unsafe {
                    (*p.0.add(i)).write(value);
                }
            }
        });
    }
    // SAFETY: parallel_chunks covers [0, n) exactly once, so every slot is
    // initialized; Vec<MaybeUninit<T>> and Vec<T> share one layout.
    unsafe {
        let mut out = std::mem::ManuallyDrop::new(out);
        Vec::from_raw_parts(out.as_mut_ptr() as *mut T, out.len(), out.capacity())
    }
}

/// Pointer wrapper asserting cross-thread sendability for disjoint writes.
struct SendPtr<T>(*mut T);
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_range_exactly_once() {
        let counter = AtomicUsize::new(0);
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(1000, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
                counter.fetch_add(1, Ordering::SeqCst);
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let v = parallel_map(100, 3, |i| i * i);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * i);
        }
    }

    /// The satellite fix: result types need neither `Default` nor `Clone`.
    #[test]
    fn map_without_default_or_clone() {
        struct Opaque(usize);
        let v = parallel_map(64, 4, Opaque);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(x.0, i);
        }
        // and with heap-owning results (drops must be exact, no leaks of
        // *initialized* slots on the happy path)
        let v = parallel_map(17, 4, |i| vec![i; i + 1]);
        assert_eq!(v[16], vec![16; 17]);
    }

    #[test]
    fn map_init_state_per_chunk() {
        // each chunk gets exactly one init() call
        let inits = AtomicUsize::new(0);
        let v = parallel_map_init(
            100,
            4,
            || {
                inits.fetch_add(1, Ordering::SeqCst);
                0usize
            },
            |i, seen| {
                *seen += 1;
                (i, *seen)
            },
        );
        assert!(inits.load(Ordering::SeqCst) <= 4);
        // within a chunk the state accumulates, and indexes stay ordered
        for (i, &(idx, seen)) in v.iter().enumerate() {
            assert_eq!(idx, i);
            assert!(seen >= 1);
        }
    }

    #[test]
    fn zero_items() {
        parallel_chunks(0, 4, |_, _| panic!("must not run with n=0 range"));
        let v: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(v.is_empty());
        let v: Vec<usize> =
            parallel_map_init(0, 4, || panic!("no init for n=0"), |i, _: &mut ()| i);
        assert!(v.is_empty());
    }

    #[test]
    fn single_thread_path() {
        let v = parallel_map(10, 1, |i| i + 1);
        assert_eq!(v, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn more_threads_than_items() {
        let v = parallel_map(3, 16, |i| i);
        assert_eq!(v, vec![0, 1, 2]);
    }
}
