//! Deterministic pseudo-random number generation.
//!
//! `SplitMix64` seeds `Xoshiro256**`; both are tiny, fast and reproducible
//! across platforms, which matters because every synthetic dataset, k-means
//! initialization and property test in the repo derives from an explicit
//! seed recorded in EXPERIMENTS.md.

/// SplitMix64: used to expand a single `u64` seed into a full RNG state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the workhorse RNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with f64 precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's method, bias negligible).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (floyd's algorithm for small
    /// k, shuffle for large k).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            all.sort_unstable();
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            for j in n - k..n {
                let t = self.below(j + 1);
                if !chosen.insert(t) {
                    chosen.insert(j);
                }
            }
            let mut v: Vec<usize> = chosen.into_iter().collect();
            v.sort_unstable();
            v
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for bound in [1usize, 2, 3, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn below_covers_all_values() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::new(3);
        for (n, k) in [(100, 10), (100, 90), (5, 5), (1, 1), (1000, 2)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
