//! Minimal JSON value model: writer + parser.
//!
//! The runtime's artifact manifest and the coordinator's metrics dump both
//! speak JSON; with serde excluded from the vendored crate set, this module
//! implements the needed subset (objects, arrays, strings, numbers, bools,
//! null) with full escaping on write and a strict recursive-descent parser.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object (panics if not an object — builder use only).
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value);
            }
            _ => panic!("set() on non-object"),
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent + 1);
        let pad_close = "  ".repeat(indent);
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    x.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_close);
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&pad_close);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    /// Parse a JSON document (strict; trailing garbage is an error).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing characters at byte {pos}"));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{x}");
        }
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end of input".into());
    }
    match b[*pos] {
        b'n' => expect_lit(b, pos, "null", Json::Null),
        b't' => expect_lit(b, pos, "true", Json::Bool(true)),
        b'f' => expect_lit(b, pos, "false", Json::Bool(false)),
        b'"' => parse_string(b, pos).map(Json::Str),
        b'[' => {
            *pos += 1;
            let mut v = Vec::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b']' {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            loop {
                v.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(v));
                    }
                    _ => return Err(format!("expected , or ] at byte {pos:?}")),
                }
            }
        }
        b'{' => {
            *pos += 1;
            let mut m = BTreeMap::new();
            skip_ws(b, pos);
            if *pos < b.len() && b[*pos] == b'}' {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                if b.get(*pos) != Some(&b':') {
                    return Err(format!("expected : at byte {pos:?}"));
                }
                *pos += 1;
                let val = parse_value(b, pos)?;
                m.insert(key, val);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(m));
                    }
                    _ => return Err(format!("expected , or }} at byte {pos:?}")),
                }
            }
        }
        b'-' | b'0'..=b'9' => parse_number(b, pos),
        c => Err(format!("unexpected byte {:?} at {pos:?}", c as char)),
    }
}

fn expect_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {pos:?}"))
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos:?}"));
    }
    *pos += 1;
    let mut s = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(s);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u escape".to_string())?;
                        let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                        s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    _ => return Err("bad escape".into()),
                }
                *pos += 1;
            }
            _ => {
                // advance one UTF-8 codepoint
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = rest.chars().next().unwrap();
                s.push(c);
                *pos += c.len_utf8();
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let txt = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    txt.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number {txt:?}: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let mut o = Json::obj();
        o.set("name", Json::Str("fastscan".into()))
            .set("m", Json::Num(16.0))
            .set("ok", Json::Bool(true))
            .set("none", Json::Null)
            .set("shape", Json::Arr(vec![Json::Num(32.0), Json::Num(16.0)]));
        let text = o.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn parses_pretty_output() {
        let mut o = Json::obj();
        o.set("a", Json::Arr(vec![Json::Num(1.0), Json::Str("x\"y".into())]));
        let back = Json::parse(&o.to_pretty()).unwrap();
        assert_eq!(back, o);
    }

    #[test]
    fn string_escapes() {
        let s = Json::Str("tab\there \"quoted\" \\ \n end".into());
        let back = Json::parse(&s.to_string()).unwrap();
        assert_eq!(back, s);
    }

    #[test]
    fn numbers() {
        for (txt, val) in [("0", 0.0), ("-3", -3.0), ("2.5", 2.5), ("1e3", 1000.0), ("-1.5e-2", -0.015)] {
            assert_eq!(Json::parse(txt).unwrap().as_f64().unwrap(), val);
        }
        // integral floats print without decimal point
        assert_eq!(Json::Num(42.0).to_string(), "42");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123 456").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse("\"\\u00e9\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn nested_access() {
        let v = Json::parse(r#"{"kernel":{"m":16,"variants":["a","b"]}}"#).unwrap();
        let k = v.get("kernel").unwrap();
        assert_eq!(k.get("m").unwrap().as_usize().unwrap(), 16);
        assert_eq!(k.get("variants").unwrap().as_arr().unwrap().len(), 2);
    }
}
