//! Top-k selection utilities.
//!
//! ANN search needs "keep the k smallest distances seen so far" in the
//! innermost loop, so this is a bounded *max*-heap specialized for
//! `(f32 distance, i64 label)` pairs plus a faster u16 reservoir used by the
//! fastscan kernel before the exact re-ranking pass.

/// Bounded max-heap keeping the `k` smallest `(distance, label)` pairs.
///
/// Push is `O(log k)` only when the candidate beats the current worst;
/// otherwise a single comparison.
#[derive(Clone, Debug)]
pub struct TopK {
    k: usize,
    /// Binary max-heap laid out in a plain vec: `heap[0]` is the worst kept.
    heap: Vec<(f32, i64)>,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        Self::from_storage(k, Vec::with_capacity(k))
    }

    /// [`TopK::new`] on recycled backing storage (cleared, capacity kept) —
    /// the executor's scratch path: a warmed-up arena re-ranks without
    /// allocating.
    pub fn from_storage(k: usize, mut heap: Vec<(f32, i64)>) -> Self {
        assert!(k > 0, "k must be positive");
        heap.clear();
        Self { k, heap }
    }

    /// Recover the backing storage (contents unspecified) for reuse.
    pub fn into_storage(self) -> Vec<(f32, i64)> {
        self.heap
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Current admission threshold: candidates with distance >= this are
    /// rejected. `INFINITY` until the heap is full.
    #[inline]
    pub fn threshold(&self) -> f32 {
        if self.heap.len() < self.k {
            f32::INFINITY
        } else {
            self.heap[0].0
        }
    }

    /// Offer a candidate.
    #[inline]
    pub fn push(&mut self, dist: f32, label: i64) {
        if self.heap.len() < self.k {
            self.heap.push((dist, label));
            self.sift_up(self.heap.len() - 1);
        } else if dist < self.heap[0].0 {
            self.heap[0] = (dist, label);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 > self.heap[parent].0 {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let l = 2 * i + 1;
            let r = 2 * i + 2;
            let mut largest = i;
            if l < n && self.heap[l].0 > self.heap[largest].0 {
                largest = l;
            }
            if r < n && self.heap[r].0 > self.heap[largest].0 {
                largest = r;
            }
            if largest == i {
                break;
            }
            self.heap.swap(i, largest);
            i = largest;
        }
    }

    /// Drain into `(distances, labels)` sorted ascending by distance.
    /// Pads with `(INFINITY, -1)` up to `k` if fewer were pushed.
    pub fn into_sorted(mut self) -> (Vec<f32>, Vec<i64>) {
        self.heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let mut d: Vec<f32> = self.heap.iter().map(|p| p.0).collect();
        let mut l: Vec<i64> = self.heap.iter().map(|p| p.1).collect();
        while d.len() < self.k {
            d.push(f32::INFINITY);
            l.push(-1);
        }
        (d, l)
    }

    /// Drain into `(distance, label)` pairs sorted ascending, **without**
    /// padding — the variable-length form the typed query API returns
    /// (same ordering as [`TopK::into_sorted`]).
    pub fn into_hits(mut self) -> Vec<(f32, i64)> {
        self.as_sorted_hits();
        self.heap
    }

    /// Sort the kept pairs ascending by `(distance, label)` in place and
    /// borrow them — the storage-reuse form of [`TopK::into_hits`]: copy
    /// the slice out, then reclaim the buffer via [`TopK::into_storage`].
    pub fn as_sorted_hits(&mut self) -> &[(f32, i64)] {
        self.heap.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        &self.heap
    }
}

/// Reservoir of candidate ids admitted by a coarse `u16` distance threshold.
///
/// The fastscan kernel produces quantized u16 distances; exact distances are
/// only computed for reservoir survivors during re-ranking (the paper's
/// implementation does the same — `HeapWithBuckets` in faiss). The reservoir
/// over-collects by `factor` relative to the requested k.
#[derive(Clone, Debug)]
pub struct U16Reservoir {
    capacity: usize,
    pub items: Vec<(u16, i64)>,
    /// Current coarse admission threshold.
    threshold: u16,
}

impl U16Reservoir {
    pub fn new(k: usize, factor: usize) -> Self {
        let capacity = (k * factor).max(k);
        Self::from_storage(k, factor, Vec::with_capacity(2 * capacity))
    }

    /// [`U16Reservoir::new`] on recycled backing storage (cleared, capacity
    /// kept): identical admission behavior, zero allocations once the
    /// buffer has grown to `2 × capacity`.
    pub fn from_storage(k: usize, factor: usize, mut items: Vec<(u16, i64)>) -> Self {
        let capacity = (k * factor).max(k);
        items.clear();
        // `push` shrinks at 2 × capacity, so this is the buffer's final
        // size: reserving it up front makes later pushes allocation-free.
        items.reserve(2 * capacity);
        Self { capacity, items, threshold: u16::MAX }
    }

    #[inline]
    pub fn threshold(&self) -> u16 {
        self.threshold
    }

    /// Whether the reservoir holds at least `capacity` candidates. Below
    /// capacity every candidate is admitted — see [`U16Reservoir::push`].
    #[inline]
    pub fn is_full(&self) -> bool {
        self.items.len() >= self.capacity
    }

    /// Offer a candidate with coarse distance `d`.
    ///
    /// Admission rule: anything goes while the reservoir is below
    /// capacity; once full, only `d < threshold` survives. The strict
    /// compare alone would starve distances saturated at `u16::MAX`
    /// (threshold starts at `u16::MAX`), returning fewer than `k` results
    /// for a database of far-away vectors even when `n >= k`.
    #[inline]
    pub fn push(&mut self, d: u16, label: i64) {
        if d >= self.threshold && self.items.len() >= self.capacity {
            return;
        }
        self.items.push((d, label));
        if self.items.len() >= 2 * self.capacity {
            self.shrink();
        }
    }

    /// Median-select down to `capacity`, tightening the threshold.
    fn shrink(&mut self) {
        let cap = self.capacity;
        self.items.select_nth_unstable_by_key(cap - 1, |p| p.0);
        self.items.truncate(cap);
        // Tighten: anything worse than the current worst kept is pointless.
        self.threshold = self.items.iter().map(|p| p.0).max().unwrap_or(u16::MAX);
    }

    /// Final candidate set (unordered).
    pub fn into_candidates(mut self) -> Vec<(u16, i64)> {
        if self.items.len() > self.capacity {
            self.shrink();
        }
        self.items
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn topk_keeps_smallest() {
        let mut t = TopK::new(3);
        for (d, l) in [(5.0, 0), (1.0, 1), (4.0, 2), (2.0, 3), (3.0, 4)] {
            t.push(d, l);
        }
        let (d, l) = t.into_sorted();
        assert_eq!(l, vec![1, 3, 4]);
        assert_eq!(d, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn topk_pads_when_underfull() {
        let mut t = TopK::new(4);
        t.push(1.5, 7);
        let (d, l) = t.into_sorted();
        assert_eq!(l, vec![7, -1, -1, -1]);
        assert_eq!(d[0], 1.5);
        assert!(d[1].is_infinite());
    }

    #[test]
    fn topk_threshold_tracks_worst() {
        let mut t = TopK::new(2);
        assert!(t.threshold().is_infinite());
        t.push(3.0, 0);
        t.push(1.0, 1);
        assert_eq!(t.threshold(), 3.0);
        t.push(2.0, 2);
        assert_eq!(t.threshold(), 2.0);
    }

    #[test]
    fn topk_matches_full_sort_randomized() {
        let mut rng = Rng::new(99);
        for trial in 0..50 {
            let n = 1 + rng.below(500);
            let k = 1 + rng.below(20);
            let dists: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut t = TopK::new(k);
            for (i, &d) in dists.iter().enumerate() {
                t.push(d, i as i64);
            }
            let (got_d, _) = t.into_sorted();
            let mut sorted = dists.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for i in 0..k.min(n) {
                assert_eq!(got_d[i], sorted[i], "trial {trial} rank {i}");
            }
        }
    }

    #[test]
    fn reservoir_never_drops_true_topk() {
        // Property: the k best coarse distances always survive the reservoir.
        let mut rng = Rng::new(7);
        for _ in 0..30 {
            let n = 100 + rng.below(2000);
            let k = 1 + rng.below(10);
            let ds: Vec<u16> = (0..n).map(|_| (rng.next_u32() & 0xFFFF) as u16).collect();
            let mut r = U16Reservoir::new(k, 4);
            for (i, &d) in ds.iter().enumerate() {
                r.push(d, i as i64);
            }
            let cands = r.into_candidates();
            let mut sorted = ds.clone();
            sorted.sort_unstable();
            let kth = sorted[k - 1];
            // every strictly-better-than-kth element must be present
            for (i, &d) in ds.iter().enumerate() {
                if d < kth {
                    assert!(
                        cands.iter().any(|&(cd, cl)| cl == i as i64 && cd == d),
                        "lost candidate {i} with d={d} (kth={kth})"
                    );
                }
            }
        }
    }

    #[test]
    fn reservoir_bounded() {
        let mut r = U16Reservoir::new(10, 2);
        for i in 0..10_000 {
            r.push((i % 65_535) as u16, i as i64);
        }
        assert!(r.into_candidates().len() <= 40);
    }

    /// Saturated distances (`u16::MAX`) must still fill an underfull
    /// reservoir: a database of far-away vectors has to return k results.
    #[test]
    fn reservoir_admits_saturated_distances_until_capacity() {
        let k = 8;
        let mut r = U16Reservoir::new(k, 4);
        assert!(!r.is_full());
        for i in 0..100 {
            r.push(u16::MAX, i as i64);
        }
        let cands = r.into_candidates();
        assert!(cands.len() >= k, "only {} of {k} saturated candidates kept", cands.len());
        assert!(cands.iter().all(|&(d, _)| d == u16::MAX));
    }

    #[test]
    fn reservoir_is_full_transitions() {
        let mut r = U16Reservoir::new(2, 2); // capacity 4
        for i in 0..4 {
            assert!(!r.is_full(), "full after only {i} pushes");
            r.push(100, i as i64);
        }
        assert!(r.is_full());
        // once full, worse-than-threshold candidates are rejected again
        let before = r.items.len();
        r.push(u16::MAX, 99);
        assert_eq!(r.items.len(), before);
    }
}
