//! Micro/throughput bench harness (criterion is not in the vendored crate
//! set). Benches under `rust/benches/` use `harness = false` and drive this.
//!
//! `BenchRunner` does warmup, adaptive iteration-count selection and reports
//! median-of-runs; `Table` renders the paper-style rows to stdout and to a
//! machine-readable JSON lines file under `bench_results/`.

use crate::util::json::Json;
use crate::util::timer::Timer;

/// Prevent the optimizer from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// One measured bench result.
#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    /// median seconds per iteration
    pub sec_per_iter: f64,
    pub iters: usize,
    pub runs: usize,
}

impl Measurement {
    pub fn ns_per_iter(&self) -> f64 {
        self.sec_per_iter * 1e9
    }
    pub fn ms_per_iter(&self) -> f64 {
        self.sec_per_iter * 1e3
    }
    pub fn per_sec(&self) -> f64 {
        if self.sec_per_iter > 0.0 {
            1.0 / self.sec_per_iter
        } else {
            0.0
        }
    }
}

/// Adaptive bench runner: picks an iteration count that makes each run last
/// ~`target_run_s`, executes `runs` runs, reports the median.
pub struct BenchRunner {
    pub target_run_s: f64,
    pub runs: usize,
    pub warmup_s: f64,
}

impl Default for BenchRunner {
    fn default() -> Self {
        // ARMPQ_BENCH_FAST=1 shrinks budgets for CI smoke runs.
        if std::env::var("ARMPQ_BENCH_FAST").as_deref() == Ok("1") {
            Self { target_run_s: 0.05, runs: 3, warmup_s: 0.02 }
        } else {
            Self { target_run_s: 0.3, runs: 5, warmup_s: 0.1 }
        }
    }
}

impl BenchRunner {
    /// Measure `f` (one logical iteration per call).
    pub fn bench<F: FnMut()>(&self, name: &str, mut f: F) -> Measurement {
        // warmup + calibration
        let mut iters = 1usize;
        loop {
            let t = Timer::start();
            for _ in 0..iters {
                f();
            }
            let el = t.elapsed_s();
            if el >= self.warmup_s || el >= self.target_run_s {
                let per = el / iters as f64;
                iters = ((self.target_run_s / per.max(1e-12)).ceil() as usize).max(1);
                break;
            }
            iters = iters.saturating_mul(4);
        }
        let mut samples = Vec::with_capacity(self.runs);
        for _ in 0..self.runs {
            let t = Timer::start();
            for _ in 0..iters {
                f();
            }
            samples.push(t.elapsed_s() / iters as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        Measurement { name: name.to_string(), sec_per_iter: median, iters, runs: self.runs }
    }
}

/// Paper-style result table: aligned stdout rendering + JSONL persistence.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render aligned to stdout.
    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            s
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", line(row));
        }
    }

    /// Append to `bench_results/<slug>.jsonl` for later analysis.
    pub fn save(&self) -> std::io::Result<()> {
        std::fs::create_dir_all("bench_results")?;
        let slug: String = self
            .title
            .chars()
            .map(|c| if c.is_alphanumeric() { c.to_ascii_lowercase() } else { '_' })
            .collect();
        let path = format!("bench_results/{slug}.jsonl");
        let mut lines = String::new();
        for row in &self.rows {
            let mut o = Json::obj();
            for (h, c) in self.headers.iter().zip(row) {
                match c.parse::<f64>() {
                    Ok(x) => o.set(h, Json::Num(x)),
                    Err(_) => o.set(h, Json::Str(c.clone())),
                };
            }
            lines.push_str(&o.to_string());
            lines.push('\n');
        }
        std::fs::write(path, lines)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = BenchRunner { target_run_s: 0.01, runs: 3, warmup_s: 0.002 };
        let mut acc = 0u64;
        let m = r.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(m.sec_per_iter > 0.0);
        assert!(m.iters >= 1);
        assert_eq!(m.runs, 3);
        assert!(m.per_sec() > 0.0);
    }

    #[test]
    fn table_row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.rows.len(), 1);
    }

    #[test]
    #[should_panic]
    fn table_rejects_bad_row() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn measurement_units() {
        let m = Measurement { name: "x".into(), sec_per_iter: 0.002, iters: 10, runs: 3 };
        assert!((m.ms_per_iter() - 2.0).abs() < 1e-9);
        assert!((m.ns_per_iter() - 2e6).abs() < 1.0);
        assert!((m.per_sec() - 500.0).abs() < 1e-6);
    }
}
