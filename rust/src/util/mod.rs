//! Small self-contained substrates: RNG, timing, top-k heaps, a JSON writer,
//! a CLI argument parser and a scoped thread pool.
//!
//! The vendored crate set intentionally excludes heavyweight dependencies
//! (tokio / clap / serde / criterion / rayon), so the pieces the system needs
//! are implemented here from scratch and unit-tested in place.

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;
pub mod threads;
pub mod timer;
pub mod topk;

/// Euclidean (squared L2) distance between two equal-length slices.
///
/// The innermost primitive of every exact scan in the crate; written with a
/// 4-way unrolled accumulator so LLVM reliably vectorizes it.
#[inline]
pub fn l2_sq(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        s0 += d0 * d0;
        s1 += d1 * d1;
        s2 += d2 * d2;
        s3 += d3 * d3;
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Inner product between two equal-length slices (unrolled like [`l2_sq`]).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for i in 0..chunks {
        let j = i * 4;
        s0 += a[j] * b[j];
        s1 += a[j + 1] * b[j + 1];
        s2 += a[j + 2] * b[j + 2];
        s3 += a[j + 3] * b[j + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for j in chunks * 4..n {
        s += a[j] * b[j];
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_matches_naive() {
        let a: Vec<f32> = (0..37).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..37).map(|i| (36 - i) as f32 * 0.25).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!((l2_sq(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f32> = (0..19).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..19).map(|i| (i * 2) as f32).collect();
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-3);
    }

    #[test]
    fn l2_zero_len() {
        assert_eq!(l2_sq(&[], &[]), 0.0);
    }
}
