//! Tiny CLI argument parser (clap is not in the vendored crate set).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; typed getters with defaults; collects unknown keys so the CLI
//! can reject typos.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (not including argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option with default.
    pub fn get_str(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn get_opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.options.get(key).cloned()
    }

    /// usize option with default (panics with a clear message on bad input).
    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.mark(key);
        match self.options.get(key) {
            None => default,
            Some(v) => v
                .replace('_', "")
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// u64 option with default.
    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.mark(key);
        match self.options.get(key) {
            None => default,
            Some(v) => v
                .replace('_', "")
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// f64 option with default.
    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.mark(key);
        match self.options.get(key) {
            None => default,
            Some(v) => {
                v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}"))
            }
        }
    }

    /// Comma-separated list of usizes, e.g. `--m 8,16,32`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        self.mark(key);
        match self.options.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{key} expects integers, got {p:?}"))
                })
                .collect(),
        }
    }

    /// Boolean flag (present or `--key true/false`).
    pub fn get_flag(&self, key: &str) -> bool {
        self.mark(key);
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Keys provided by the user but never read by the command — typos.
    pub fn unknown_keys(&self) -> Vec<String> {
        let seen = self.consumed.borrow();
        self.options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn basic_forms() {
        let a = mk(&["search", "--n", "1000", "--name=deep", "--verbose", "--k", "10"]);
        assert_eq!(a.positional, vec!["search"]);
        assert_eq!(a.get_usize("n", 1), 1000);
        assert_eq!(a.get_str("name", "x"), "deep");
        assert!(a.get_flag("verbose"));
        assert_eq!(a.get_usize("k", 1), 10);
        assert_eq!(a.get_usize("missing", 7), 7);
    }

    #[test]
    fn underscore_numbers() {
        let a = mk(&["--n", "1_000_000"]);
        assert_eq!(a.get_usize("n", 0), 1_000_000);
    }

    #[test]
    fn lists() {
        let a = mk(&["--m", "8,16,32"]);
        assert_eq!(a.get_usize_list("m", &[4]), vec![8, 16, 32]);
        assert_eq!(a.get_usize_list("x", &[4]), vec![4]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = mk(&["--fast", "--safe"]);
        assert!(a.get_flag("fast"));
        assert!(a.get_flag("safe"));
    }

    #[test]
    fn bool_as_value() {
        let a = mk(&["--rerank", "true", "--residual", "false"]);
        assert!(a.get_flag("rerank"));
        assert!(!a.get_flag("residual"));
    }

    #[test]
    fn unknown_keys_detected() {
        let a = mk(&["--good", "1", "--typo", "2"]);
        let _ = a.get_usize("good", 0);
        assert_eq!(a.unknown_keys(), vec!["typo".to_string()]);
    }

    #[test]
    fn f64_parse() {
        let a = mk(&["--timeout", "2.5"]);
        assert!((a.get_f64("timeout", 0.0) - 2.5).abs() < 1e-12);
    }
}
