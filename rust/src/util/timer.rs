//! Wall-clock timing and latency statistics.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }

    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Online latency statistics (stores all samples; fine for bench scale).
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean_ms(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    /// Percentile in [0, 100] by nearest-rank on the sorted samples.
    pub fn percentile_ms(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn min_ms(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    pub fn max_ms(&self) -> f64 {
        self.samples_ms.iter().cloned().fold(0.0, f64::max)
    }

    /// Queries per second implied by the mean latency (single stream).
    pub fn qps(&self) -> f64 {
        let m = self.mean_ms();
        if m <= 0.0 {
            0.0
        } else {
            1e3 / m
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles() {
        let mut s = LatencyStats::new();
        for i in 1..=100 {
            s.record_ms(i as f64);
        }
        assert_eq!(s.count(), 100);
        assert!((s.mean_ms() - 50.5).abs() < 1e-9);
        assert_eq!(s.percentile_ms(0.0), 1.0);
        assert_eq!(s.percentile_ms(100.0), 100.0);
        let p50 = s.percentile_ms(50.0);
        assert!((49.0..=52.0).contains(&p50));
        assert_eq!(s.min_ms(), 1.0);
        assert_eq!(s.max_ms(), 100.0);
    }

    #[test]
    fn qps_inverse_of_mean() {
        let mut s = LatencyStats::new();
        s.record_ms(2.0);
        s.record_ms(2.0);
        assert!((s.qps() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let s = LatencyStats::new();
        assert_eq!(s.mean_ms(), 0.0);
        assert_eq!(s.percentile_ms(50.0), 0.0);
        assert_eq!(s.qps(), 0.0);
    }

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(t.elapsed_ms() >= 1.0);
        assert!(t.elapsed_us() >= t.elapsed_ms());
    }
}
