//! The 4-bit interleaved block code layout.
//!
//! "Note that we must carefully maintain the code layout [8, 9]" (paper §3):
//! the shuffle kernel only works if one aligned 32-byte load yields, for a
//! *pair* of sub-quantizers, the 4-bit codes of 32 consecutive database
//! vectors arranged so that nibble extraction produces shuffle-ready index
//! registers whose lanes line up with the right lookup tables.
//!
//! Layout used here (faiss `pq4_pack_codes` structure):
//!
//! * Vectors are grouped into **blocks of 32** ([`crate::pq::BLOCK_SIZE`]).
//! * Within a block, sub-quantizers are packed in **pairs** `(q, q+1)`;
//!   each pair owns 32 contiguous bytes:
//!   - byte `i`      (i < 16): `code_q(v_i)      | code_q(v_{i+16})   << 4`
//!   - byte `16 + i` (i < 16): `code_{q+1}(v_i)  | code_{q+1}(v_{i+16}) << 4`
//!
//! So after the 256-bit load `c`:
//! `c & 0xF`   = lane-lo: codes of `q` for v₀..v₁₅, lane-hi: codes of `q+1`
//! for v₀..v₁₅ — exactly the `(T¹, T²)` dual-table shuffle of Fig. 1c; and
//! `(c >> 4) & 0xF` = the same for v₁₆..v₃₁.
//!
//! Odd `M` is padded with a phantom sub-quantizer whose LUT is all-zero, so
//! it never affects distances.

use crate::pq::BLOCK_SIZE;
use crate::{Error, Result};

/// Packed 4-bit codes in the interleaved block layout.
#[derive(Clone, Debug)]
pub struct PackedCodes4 {
    /// Number of real (unpadded) vectors.
    pub n: usize,
    /// Number of real sub-quantizers (before padding to even).
    pub m: usize,
    /// M rounded up to even — the packed stride uses this.
    pub m_pad: usize,
    /// Packed bytes: `nblocks × (m_pad/2) × 32`.
    pub data: Vec<u8>,
}

impl PackedCodes4 {
    /// Bytes per block: `(m_pad / 2) × 32 = 16 × m_pad`.
    #[inline]
    pub fn block_bytes(&self) -> usize {
        16 * self.m_pad
    }

    /// Number of 32-vector blocks (last one padded).
    #[inline]
    pub fn nblocks(&self) -> usize {
        self.n.div_ceil(BLOCK_SIZE)
    }

    /// The 32-byte chunk of block `b`, sub-quantizer pair `p`.
    #[inline]
    pub fn pair_chunk(&self, b: usize, p: usize) -> &[u8] {
        let off = b * self.block_bytes() + p * 32;
        &self.data[off..off + 32]
    }

    /// Pack flat codes (`n × m`, one byte per sub-quantizer, values < 16).
    pub fn pack(codes: &[u8], m: usize) -> Result<Self> {
        if m == 0 || codes.len() % m != 0 {
            return Err(Error::InvalidParameter(format!(
                "codes length {} not divisible by m {m}",
                codes.len()
            )));
        }
        if let Some(&bad) = codes.iter().find(|&&c| c >= 16) {
            return Err(Error::InvalidParameter(format!(
                "4-bit packing requires codes < 16, found {bad}"
            )));
        }
        let n = codes.len() / m;
        let m_pad = m.div_ceil(2) * 2;
        let nblocks = n.div_ceil(BLOCK_SIZE);
        let mut data = vec![0u8; nblocks * 16 * m_pad];

        for i in 0..n {
            let b = i / BLOCK_SIZE;
            let v = i % BLOCK_SIZE; // position within block
            let base = b * 16 * m_pad;
            for q in 0..m {
                let code = codes[i * m + q];
                let p = q / 2; // pair index
                let within = q % 2; // 0 → bytes 0..16, 1 → bytes 16..32
                let byte_idx = base + p * 32 + within * 16 + (v % 16);
                if v < 16 {
                    data[byte_idx] |= code; // low nibble: vectors 0..16
                } else {
                    data[byte_idx] |= code << 4; // high nibble: vectors 16..32
                }
            }
        }
        Ok(Self { n, m, m_pad, data })
    }

    /// Unpack back to flat `n × m` codes (inverse of [`PackedCodes4::pack`];
    /// used by tests and by the re-ranking pass).
    pub fn unpack(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.n * self.m];
        for i in 0..self.n {
            for q in 0..self.m {
                out[i * self.m + q] = self.code_at(i, q);
            }
        }
        out
    }

    /// Code of vector `i`, sub-quantizer `q` (slow path — scan kernels never
    /// call this; re-ranking and tests do).
    #[inline]
    pub fn code_at(&self, i: usize, q: usize) -> u8 {
        let b = i / BLOCK_SIZE;
        let v = i % BLOCK_SIZE;
        let p = q / 2;
        let within = q % 2;
        let byte = self.data[b * 16 * self.m_pad + p * 32 + within * 16 + (v % 16)];
        if v < 16 {
            byte & 0x0F
        } else {
            byte >> 4
        }
    }

    /// Memory used per vector, in bits (the paper's "4M bits" claim).
    pub fn bits_per_vector(&self) -> f64 {
        (self.data.len() * 8) as f64 / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_codes(n: usize, m: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n * m).map(|_| (rng.next_u32() % 16) as u8).collect()
    }

    #[test]
    fn pack_unpack_roundtrip() {
        for (n, m) in [(32, 8), (100, 16), (1, 2), (33, 4), (64, 6), (200, 15)] {
            let codes = random_codes(n, m, n as u64 * 31 + m as u64);
            let packed = PackedCodes4::pack(&codes, m).unwrap();
            assert_eq!(packed.unpack(), codes, "n={n} m={m}");
        }
    }

    #[test]
    fn layout_matches_spec_exactly() {
        // hand-check the byte layout formula for a full block
        let n = 32;
        let m = 4;
        let codes = random_codes(n, m, 55);
        let packed = PackedCodes4::pack(&codes, m).unwrap();
        for q in 0..m {
            let p = q / 2;
            let within = q % 2;
            for i in 0..16 {
                let byte = packed.data[p * 32 + within * 16 + i];
                assert_eq!(byte & 0xF, codes[i * m + q], "lo nibble q={q} i={i}");
                assert_eq!(byte >> 4, codes[(i + 16) * m + q], "hi nibble q={q} i={i}");
            }
        }
    }

    #[test]
    fn nibble_extraction_feeds_correct_lanes() {
        // End-to-end check of the §3 claim: after load + nibble mask, lane
        // lo holds sub-quantizer q codes and lane hi holds q+1 codes.
        use crate::simd::Simd256u8;
        let n = 32;
        let m = 2;
        let codes = random_codes(n, m, 56);
        let packed = PackedCodes4::pack(&codes, m).unwrap();
        let c = Simd256u8::load(packed.pair_chunk(0, 0));
        let mask = Simd256u8::splat(0x0F);
        let clo = c.and(mask);
        let chi = c.shr4().and(mask);
        let mut lo_b = [0u8; 32];
        let mut hi_b = [0u8; 32];
        clo.store(&mut lo_b);
        chi.store(&mut hi_b);
        for i in 0..16 {
            assert_eq!(lo_b[i], codes[i * m], "clo lane-lo v{i} = q0");
            assert_eq!(lo_b[16 + i], codes[i * m + 1], "clo lane-hi v{i} = q1");
            assert_eq!(hi_b[i], codes[(16 + i) * m], "chi lane-lo v{} = q0", 16 + i);
            assert_eq!(hi_b[16 + i], codes[(16 + i) * m + 1], "chi lane-hi = q1");
        }
    }

    #[test]
    fn partial_last_block_zero_padded() {
        let codes = random_codes(5, 4, 57);
        let packed = PackedCodes4::pack(&codes, 4).unwrap();
        assert_eq!(packed.nblocks(), 1);
        // codes of phantom vectors 5..32 must read back as 0
        for i in 5..32 {
            for q in 0..4 {
                // construct a fake reader past n — code_at works on layout
                let b = 0;
                let v = i;
                let p = q / 2;
                let within = q % 2;
                let byte = packed.data[b * 16 * 4 + p * 32 + within * 16 + (v % 16)];
                let val = if v < 16 { byte & 0xF } else { byte >> 4 };
                assert_eq!(val, 0, "phantom vector {i} q {q}");
            }
        }
    }

    #[test]
    fn odd_m_padding() {
        let codes = random_codes(40, 3, 58);
        let packed = PackedCodes4::pack(&codes, 3).unwrap();
        assert_eq!(packed.m_pad, 4);
        assert_eq!(packed.block_bytes(), 64);
        assert_eq!(packed.unpack(), codes);
        // phantom sub-quantizer (q=3) codes are all zero
        for i in 0..40 {
            let b = i / 32;
            let v = i % 32;
            let byte = packed.data[b * 64 + 32 + 16 + (v % 16)];
            let val = if v < 16 { byte & 0xF } else { byte >> 4 };
            assert_eq!(val, 0);
        }
    }

    #[test]
    fn four_bits_per_code() {
        // paper: "for a 4-bit PQ with K=16, the cost is 4M bits"
        let codes = random_codes(32 * 100, 16, 59);
        let packed = PackedCodes4::pack(&codes, 16).unwrap();
        assert_eq!(packed.bits_per_vector(), 64.0); // 4 × M=16
    }

    #[test]
    fn rejects_big_codes() {
        assert!(PackedCodes4::pack(&[0, 16], 2).is_err());
    }

    #[test]
    fn rejects_ragged_input() {
        assert!(PackedCodes4::pack(&[0, 1, 2], 2).is_err());
    }
}
