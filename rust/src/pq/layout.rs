//! Width-parametric interleaved block code layouts.
//!
//! "Note that we must carefully maintain the code layout [8, 9]" (paper
//! §3): the shuffle kernel only works if one aligned 32-byte load yields a
//! chunk whose nibbles line up with the right 16-entry lookup tables. The
//! layout is parametric over [`CodeWidth`]; per 32-vector block
//! ([`crate::pq::BLOCK_SIZE`]) each width owns `CodeWidth::chunks(m)`
//! 32-byte chunks:
//!
//! * **4-bit** (faiss `pq4_pack_codes` structure, the paper's layout):
//!   chunk `p` holds sub-quantizer pair `(q, q+1) = (2p, 2p+1)`:
//!   - byte `i`      (i < 16): `code_q(v_i)      | code_q(v_{i+16})   << 4`
//!   - byte `16 + i` (i < 16): `code_{q+1}(v_i)  | code_{q+1}(v_{i+16}) << 4`
//!
//!   After the 256-bit load `c`: `c & 0xF` = lane-lo: codes of `q` for
//!   v₀..v₁₅, lane-hi: codes of `q+1` for v₀..v₁₅ — exactly the `(T¹, T²)`
//!   dual-table shuffle of Fig. 1c; `c >> 4` = the same for v₁₆..v₃₁.
//!
//! * **2-bit**: adjacent sub-quantizers fuse pairwise into 4-bit codes
//!   `c_{2P} | c_{2P+1} << 2` (matching the fused sum-tables of
//!   [`crate::pq::bitwidth`]), then the fused columns use the 4-bit layout
//!   above — four 2-bit codes interleaved per byte, half the chunks of
//!   4-bit at equal `M`.
//!
//! * **8-bit**: chunk `q` holds ONE user sub-quantizer's full code bytes
//!   (internal nibble-half columns `2q`/`2q+1` share a byte):
//!   - byte `i`      (i < 16): `c_{2q}(v_i)      | c_{2q+1}(v_i)      << 4`
//!   - byte `16 + i` (i < 16): `c_{2q}(v_{i+16}) | c_{2q+1}(v_{i+16}) << 4`
//!
//!   so lane-lo's nibbles are the lo/hi table indices for v₀..v₁₅ and
//!   lane-hi's for v₁₆..v₃₁ ([`crate::pq::fastscan::LaneWiring::SplitNibble`]).
//!
//! Phantom columns (odd `m` padding) and phantom vectors (partial last
//! block) are all-zero and pair with all-zero table rows, so they never
//! affect distances.

use crate::pq::bitwidth::CodeWidth;
use crate::pq::BLOCK_SIZE;
use crate::storage::CodeStore;
use crate::{Error, Result};

/// Packed codes in the width-parametric interleaved block layout.
#[derive(Clone, Debug)]
pub struct PackedCodes {
    /// Code width the layout was packed for.
    pub width: CodeWidth,
    /// Number of real (unpadded) vectors.
    pub n: usize,
    /// User-facing sub-quantizers.
    pub m: usize,
    /// Internal code columns consumed by [`PackedCodes::pack`] and returned
    /// by [`PackedCodes::code_at`]/[`PackedCodes::unpack`]
    /// (`width.code_columns(m)`).
    pub m_codes: usize,
    /// 16-entry LUT rows the matching kernel consumes
    /// (`width.lut_rows(m)`; for 4-bit this is `m` rounded up to even).
    pub lut_rows: usize,
    /// Packed bytes: `nblocks × chunks × 32` — heap-owned or a zero-copy
    /// window into a mapped index file ([`CodeStore`] derefs to `&[u8]`
    /// either way).
    pub data: CodeStore,
}

/// Byte offset within a block and bit shift of internal code column `col`
/// for block-local vector `v` — the single source of truth for the bit
/// placement, shared by the packer and the reader so they can never
/// drift apart.
#[inline]
fn locate(width: CodeWidth, col: usize, v: usize) -> (usize, usize) {
    match width {
        // fused 4-bit column P = col/2 uses the 4-bit placement; the
        // 2-bit code lands at bit offset (col%2)*2 within the nibble
        CodeWidth::W2 => {
            let fused_col = col / 2;
            let p = fused_col / 2;
            let within = fused_col % 2;
            let nib = if v < 16 { 0 } else { 4 };
            (p * 32 + within * 16 + (v % 16), nib + 2 * (col % 2))
        }
        CodeWidth::W4 => {
            let p = col / 2;
            let within = col % 2;
            (p * 32 + within * 16 + (v % 16), if v < 16 { 0 } else { 4 })
        }
        // chunk = user sub-quantizer; lo/hi nibble = lo/hi half-space code
        CodeWidth::W8 => {
            let p = col / 2;
            let half = if v < 16 { 0 } else { 16 };
            (p * 32 + half + (v % 16), 4 * (col % 2))
        }
    }
}

/// Read mask of one internal sub-code (2 bits for W2, a nibble otherwise).
#[inline]
fn sub_code_mask(width: CodeWidth) -> u8 {
    (width.sub_ksub() - 1) as u8
}

impl PackedCodes {
    /// 32-byte chunks per block.
    #[inline]
    pub fn chunks(&self) -> usize {
        self.lut_rows / 2
    }

    /// Bytes per block: `chunks × 32`.
    #[inline]
    pub fn block_bytes(&self) -> usize {
        self.chunks() * 32
    }

    /// Number of 32-vector blocks (last one padded).
    #[inline]
    pub fn nblocks(&self) -> usize {
        self.n.div_ceil(BLOCK_SIZE)
    }

    /// The 32-byte chunk of block `b`, chunk index `p`.
    #[inline]
    pub fn pair_chunk(&self, b: usize, p: usize) -> &[u8] {
        let off = b * self.block_bytes() + p * 32;
        &self.data[off..off + 32]
    }

    /// Pack flat internal codes: `n × width.code_columns(m)`, one byte per
    /// column, each value `< width.sub_ksub()`.
    pub fn pack(codes: &[u8], m: usize, width: CodeWidth) -> Result<Self> {
        let m_codes = width.code_columns(m);
        if m == 0 || codes.len() % m_codes != 0 {
            return Err(Error::InvalidParameter(format!(
                "codes length {} not divisible by {} code columns (m={m}, {width})",
                codes.len(),
                m_codes.max(1),
            )));
        }
        let sub_ksub = width.sub_ksub();
        if let Some(&bad) = codes.iter().find(|&&c| c as usize >= sub_ksub) {
            return Err(Error::InvalidParameter(format!(
                "{width} packing requires codes < {sub_ksub}, found {bad}"
            )));
        }
        let n = codes.len() / m_codes;
        let lut_rows = width.lut_rows(m);
        let nblocks = n.div_ceil(BLOCK_SIZE);
        let mut data = vec![0u8; nblocks * lut_rows * 16];
        let bb = lut_rows * 16;

        for i in 0..n {
            let b = i / BLOCK_SIZE;
            let v = i % BLOCK_SIZE;
            let base = b * bb;
            for col in 0..m_codes {
                let code = codes[i * m_codes + col];
                let (off, shift) = locate(width, col, v);
                data[base + off] |= code << shift;
            }
        }
        Ok(Self { width, n, m, m_codes, lut_rows, data: data.into() })
    }

    /// Rebuild a `PackedCodes` over an existing store of already-packed
    /// bytes (heap-loaded or a mapped window of a v3 index file). The
    /// byte count must match the layout exactly — a corrupt header that
    /// lies about `n` or `m` is rejected here instead of panicking in the
    /// scan kernels.
    pub fn from_store(data: CodeStore, n: usize, m: usize, width: CodeWidth) -> Result<Self> {
        if m == 0 {
            return Err(Error::InvalidParameter("packed codes need m >= 1".into()));
        }
        let m_codes = width.code_columns(m);
        let lut_rows = width.lut_rows(m);
        let want = n.div_ceil(BLOCK_SIZE) * lut_rows * 16;
        if data.len() != want {
            return Err(Error::CorruptIndex(format!(
                "packed region is {} bytes, layout n={n} m={m} {width} needs {want}",
                data.len()
            )));
        }
        Ok(Self { width, n, m, m_codes, lut_rows, data })
    }

    /// Bytes of this layout served zero-copy from a mapped index file
    /// (0 when heap-owned) — feeds the `bytes_mapped` query stat.
    #[inline]
    pub fn mapped_bytes(&self) -> usize {
        self.data.mapped_bytes()
    }

    /// Unpack back to flat `n × m_codes` internal codes (inverse of
    /// [`PackedCodes::pack`]; used by tests and the re-ranking pass).
    pub fn unpack(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.n * self.m_codes];
        for i in 0..self.n {
            for col in 0..self.m_codes {
                out[i * self.m_codes + col] = self.code_at(i, col);
            }
        }
        out
    }

    /// Internal code of vector `i`, column `col` (slow path — scan kernels
    /// never call this; re-ranking and tests do).
    #[inline]
    pub fn code_at(&self, i: usize, col: usize) -> u8 {
        let b = i / BLOCK_SIZE;
        let v = i % BLOCK_SIZE;
        let base = b * self.block_bytes();
        let (off, shift) = locate(self.width, col, v);
        (self.data[base + off] >> shift) & sub_code_mask(self.width)
    }

    /// Code payload per vector in bits: `width.bits() × m` exactly
    /// (the paper's "4M bits" claim, per width).
    pub fn code_bits_per_vector(&self) -> usize {
        self.width.bits() * self.m
    }

    /// *Stored* bits per vector, block/column padding included — ≥
    /// [`PackedCodes::code_bits_per_vector`], converging to it for full
    /// blocks and even column counts.
    pub fn bits_per_vector(&self) -> f64 {
        (self.data.len() * 8) as f64 / self.n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_codes(n: usize, cols: usize, ksub: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..n * cols).map(|_| (rng.next_u32() as usize % ksub) as u8).collect()
    }

    #[test]
    fn pack_unpack_roundtrip_all_widths() {
        for width in CodeWidth::ALL {
            for (n, m) in [(32, 8), (100, 16), (1, 2), (33, 4), (64, 6), (200, 15), (7, 1)] {
                let cols = width.code_columns(m);
                let codes = random_codes(n, cols, width.sub_ksub(), n as u64 * 31 + m as u64);
                let packed = PackedCodes::pack(&codes, m, width).unwrap();
                assert_eq!(packed.unpack(), codes, "{width} n={n} m={m}");
                assert_eq!(packed.m_codes, cols);
                assert_eq!(packed.code_bits_per_vector(), width.bits() * m);
            }
        }
    }

    #[test]
    fn layout_matches_spec_exactly_4bit() {
        // hand-check the byte layout formula for a full block
        let n = 32;
        let m = 4;
        let codes = random_codes(n, m, 16, 55);
        let packed = PackedCodes::pack(&codes, m, CodeWidth::W4).unwrap();
        for q in 0..m {
            let p = q / 2;
            let within = q % 2;
            for i in 0..16 {
                let byte = packed.data[p * 32 + within * 16 + i];
                assert_eq!(byte & 0xF, codes[i * m + q], "lo nibble q={q} i={i}");
                assert_eq!(byte >> 4, codes[(i + 16) * m + q], "hi nibble q={q} i={i}");
            }
        }
    }

    #[test]
    fn layout_matches_spec_exactly_2bit() {
        // one byte holds FOUR 2-bit codes: fused pair (q, q+1) × vector
        // halves (v_i, v_{i+16})
        let n = 32;
        let m = 4; // two fused columns → one chunk
        let codes = random_codes(n, m, 4, 56);
        let packed = PackedCodes::pack(&codes, m, CodeWidth::W2).unwrap();
        assert_eq!(packed.block_bytes(), 32);
        for i in 0..16 {
            for (fused, base_q) in [(0usize, 0usize), (1, 2)] {
                let byte = packed.data[fused * 16 + i];
                let lo = byte & 0x0F;
                let hi = byte >> 4;
                assert_eq!(lo & 3, codes[i * m + base_q], "v{i} q{base_q}");
                assert_eq!(lo >> 2, codes[i * m + base_q + 1], "v{i} q{}", base_q + 1);
                assert_eq!(hi & 3, codes[(i + 16) * m + base_q], "v{} q{base_q}", i + 16);
                assert_eq!(hi >> 2, codes[(i + 16) * m + base_q + 1]);
            }
        }
    }

    #[test]
    fn layout_matches_spec_exactly_8bit() {
        // chunk q: bytes 0..16 = full code bytes of v0..15, 16..32 = v16..31
        let n = 32;
        let m = 2; // cols = 4 nibble columns → two chunks
        let cols = 4;
        let codes = random_codes(n, cols, 16, 57);
        let packed = PackedCodes::pack(&codes, m, CodeWidth::W8).unwrap();
        assert_eq!(packed.block_bytes(), 64);
        for q in 0..m {
            for i in 0..16 {
                let b_lo = packed.data[q * 32 + i];
                let b_hi = packed.data[q * 32 + 16 + i];
                assert_eq!(b_lo & 0xF, codes[i * cols + 2 * q], "v{i} chunk {q} lo");
                assert_eq!(b_lo >> 4, codes[i * cols + 2 * q + 1], "v{i} chunk {q} hi");
                assert_eq!(b_hi & 0xF, codes[(16 + i) * cols + 2 * q]);
                assert_eq!(b_hi >> 4, codes[(16 + i) * cols + 2 * q + 1]);
            }
        }
    }

    #[test]
    fn nibble_extraction_feeds_correct_lanes() {
        // End-to-end check of the §3 claim: after load + nibble mask, lane
        // lo holds sub-quantizer q codes and lane hi holds q+1 codes.
        use crate::simd::Simd256u8;
        let n = 32;
        let m = 2;
        let codes = random_codes(n, m, 16, 58);
        let packed = PackedCodes::pack(&codes, m, CodeWidth::W4).unwrap();
        let c = Simd256u8::load(packed.pair_chunk(0, 0));
        let mask = Simd256u8::splat(0x0F);
        let clo = c.and(mask);
        let chi = c.shr4().and(mask);
        let mut lo_b = [0u8; 32];
        let mut hi_b = [0u8; 32];
        clo.store(&mut lo_b);
        chi.store(&mut hi_b);
        for i in 0..16 {
            assert_eq!(lo_b[i], codes[i * m], "clo lane-lo v{i} = q0");
            assert_eq!(lo_b[16 + i], codes[i * m + 1], "clo lane-hi v{i} = q1");
            assert_eq!(hi_b[i], codes[(16 + i) * m], "chi lane-lo v{} = q0", 16 + i);
            assert_eq!(hi_b[16 + i], codes[(16 + i) * m + 1], "chi lane-hi = q1");
        }
    }

    #[test]
    fn partial_last_block_zero_padded() {
        for width in CodeWidth::ALL {
            let cols = width.code_columns(4);
            let codes = random_codes(5, cols, width.sub_ksub(), 59);
            let packed = PackedCodes::pack(&codes, 4, width).unwrap();
            assert_eq!(packed.nblocks(), 1);
            // bytes belonging to phantom vectors 5..32 must read back as 0
            // through the same extraction the kernel uses
            let mut fake = packed.clone();
            fake.n = 32; // widen the view over the single padded block
            for i in 5..32 {
                for col in 0..cols {
                    assert_eq!(fake.code_at(i, col), 0, "{width} phantom v{i} col {col}");
                }
            }
        }
    }

    #[test]
    fn odd_m_padding() {
        let codes = random_codes(40, 3, 16, 60);
        let packed = PackedCodes::pack(&codes, 3, CodeWidth::W4).unwrap();
        assert_eq!(packed.lut_rows, 4);
        assert_eq!(packed.block_bytes(), 64);
        assert_eq!(packed.unpack(), codes);
        // phantom sub-quantizer (q=3) codes are all zero
        for i in 0..40 {
            let b = i / 32;
            let v = i % 32;
            let byte = packed.data[b * 64 + 32 + 16 + (v % 16)];
            let val = if v < 16 { byte & 0xF } else { byte >> 4 };
            assert_eq!(val, 0);
        }
    }

    #[test]
    fn bits_per_code_match_width() {
        // paper: "for a 4-bit PQ with K=16, the cost is 4M bits" — and the
        // 2-/8-bit layouts halve/double it exactly (full blocks, even m)
        for (width, want) in [(CodeWidth::W2, 32.0), (CodeWidth::W4, 64.0), (CodeWidth::W8, 128.0)]
        {
            let cols = width.code_columns(16);
            let codes = random_codes(32 * 100, cols, width.sub_ksub(), 61);
            let packed = PackedCodes::pack(&codes, 16, width).unwrap();
            assert_eq!(packed.bits_per_vector(), want, "{width}");
            assert_eq!(packed.code_bits_per_vector(), want as usize, "{width}");
        }
    }

    #[test]
    fn rejects_big_codes_per_width() {
        assert!(PackedCodes::pack(&[0, 16], 2, CodeWidth::W4).is_err());
        assert!(PackedCodes::pack(&[0, 4], 2, CodeWidth::W2).is_err());
        assert!(PackedCodes::pack(&[0, 16, 0, 0], 2, CodeWidth::W8).is_err());
        // the error names the width and its bound
        let e = PackedCodes::pack(&[0, 4], 2, CodeWidth::W2).unwrap_err().to_string();
        assert!(e.contains("2-bit") && e.contains("< 4"), "{e}");
    }

    #[test]
    fn from_store_roundtrip_and_validation() {
        for width in CodeWidth::ALL {
            let cols = width.code_columns(8);
            let codes = random_codes(50, cols, width.sub_ksub(), 62);
            let packed = PackedCodes::pack(&codes, 8, width).unwrap();
            let bytes: Vec<u8> = packed.data.to_vec();
            let rebuilt =
                PackedCodes::from_store(bytes.clone().into(), 50, 8, width).unwrap();
            assert_eq!(rebuilt.unpack(), codes, "{width}");
            assert_eq!(rebuilt.mapped_bytes(), 0);
            // a store that disagrees with the layout is corrupt, not UB
            let short = PackedCodes::from_store(bytes[1..].to_vec().into(), 50, 8, width);
            assert!(matches!(short.unwrap_err(), Error::CorruptIndex(_)), "{width}");
        }
        assert!(PackedCodes::from_store(Vec::new().into(), 0, 0, CodeWidth::W4).is_err());
    }

    #[test]
    fn rejects_ragged_input() {
        assert!(PackedCodes::pack(&[0, 1, 2], 2, CodeWidth::W4).is_err());
        assert!(PackedCodes::pack(&[0, 1, 2], 2, CodeWidth::W8).is_err());
        assert!(PackedCodes::pack(&[], 0, CodeWidth::W4).is_err());
    }
}
