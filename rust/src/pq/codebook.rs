//! The product quantizer itself: training, encoding, decoding, and f32
//! ADC lookup-table construction (paper §2).

use crate::kmeans::{nearest_centroid, KMeans, KMeansParams};
use crate::util::threads::{default_threads, parallel_chunks};
use crate::{Error, Result};

/// Product-quantizer hyper-parameters.
#[derive(Clone, Debug)]
pub struct PqParams {
    /// Number of sub-quantizers M (vector is split into M sub-vectors).
    pub m: usize,
    /// Codewords per sub-space. 16 → 4-bit codes (the paper's setting);
    /// 256 → classic 8-bit PQ.
    pub ksub: usize,
    /// k-means iterations for each sub-space.
    pub train_iters: usize,
    pub seed: u64,
}

impl PqParams {
    /// The paper's 4-bit configuration: `K = 16`.
    pub fn new_4bit(m: usize) -> Self {
        Self { m, ksub: 16, train_iters: 25, seed: 1234 }
    }

    /// Classic 8-bit PQ (`K = 256`).
    pub fn new_8bit(m: usize) -> Self {
        Self { m, ksub: 256, train_iters: 25, seed: 1234 }
    }

    /// Bits per code: `log2(ksub)`.
    pub fn nbits(&self) -> u32 {
        self.ksub.trailing_zeros()
    }
}

/// A trained product quantizer.
///
/// Codewords are stored row-major as `m × ksub × dsub`; codes produced by
/// [`ProductQuantizer::encode`] are one byte per sub-quantizer (packing to
/// 4 bits is the job of [`crate::pq::layout`]).
#[derive(Clone, Debug)]
pub struct ProductQuantizer {
    pub dim: usize,
    pub m: usize,
    pub ksub: usize,
    pub dsub: usize,
    /// `m × ksub × dsub` codeword tensor.
    pub centroids: Vec<f32>,
}

impl ProductQuantizer {
    /// Train on `n × dim` row-major vectors.
    pub fn train(data: &[f32], dim: usize, params: &PqParams) -> Result<Self> {
        if params.m == 0 || dim % params.m != 0 {
            return Err(Error::InvalidParameter(format!(
                "dim {dim} not divisible by m {}",
                params.m
            )));
        }
        if !params.ksub.is_power_of_two() || params.ksub < 2 {
            return Err(Error::InvalidParameter(format!(
                "ksub must be a power of two >= 2, got {}",
                params.ksub
            )));
        }
        let n = data.len() / dim;
        if n < params.ksub {
            return Err(Error::InvalidParameter(format!(
                "need >= ksub={} training vectors, got {n}",
                params.ksub
            )));
        }
        let dsub = dim / params.m;
        let mut centroids = vec![0.0f32; params.m * params.ksub * dsub];

        for mi in 0..params.m {
            // slice out sub-vectors for this sub-space
            let mut sub = vec![0.0f32; n * dsub];
            for i in 0..n {
                let src = &data[i * dim + mi * dsub..i * dim + (mi + 1) * dsub];
                sub[i * dsub..(i + 1) * dsub].copy_from_slice(src);
            }
            let mut kp = KMeansParams::new(params.ksub);
            kp.iters = params.train_iters;
            kp.seed = params.seed.wrapping_add(mi as u64);
            let km = KMeans::train(&sub, dsub, &kp)?;
            let dst = &mut centroids[mi * params.ksub * dsub..(mi + 1) * params.ksub * dsub];
            dst.copy_from_slice(&km.centroids);
        }

        Ok(Self { dim, m: params.m, ksub: params.ksub, dsub, centroids })
    }

    /// Codewords of sub-space `mi`: `ksub × dsub` row-major.
    #[inline]
    pub fn sub_centroids(&self, mi: usize) -> &[f32] {
        let sz = self.ksub * self.dsub;
        &self.centroids[mi * sz..(mi + 1) * sz]
    }

    /// Encode one vector → `m` code bytes.
    pub fn encode_one(&self, x: &[f32], out: &mut [u8]) {
        debug_assert_eq!(x.len(), self.dim);
        debug_assert!(out.len() >= self.m);
        for mi in 0..self.m {
            let sub = &x[mi * self.dsub..(mi + 1) * self.dsub];
            let (k, _) = nearest_centroid(sub, self.sub_centroids(mi), self.ksub, self.dsub);
            out[mi] = k as u8;
        }
    }

    /// Encode a batch (`n × dim`) → `n × m` code bytes, parallel over rows.
    pub fn encode(&self, xs: &[f32]) -> Result<Vec<u8>> {
        if xs.len() % self.dim != 0 {
            return Err(Error::DimMismatch { expected: self.dim, got: xs.len() % self.dim });
        }
        let n = xs.len() / self.dim;
        let mut codes = vec![0u8; n * self.m];
        let codes_ptr = CodesPtr(codes.as_mut_ptr());
        let m = self.m;
        parallel_chunks(n, default_threads(), |s, e| {
            let p = codes_ptr;
            for i in s..e {
                let row = &xs[i * self.dim..(i + 1) * self.dim];
                // SAFETY: rows are disjoint per chunk.
                let out = unsafe { std::slice::from_raw_parts_mut(p.0.add(i * m), m) };
                self.encode_one(row, out);
            }
        });
        Ok(codes)
    }

    /// Reconstruct (lossy) a vector from its `m` code bytes.
    pub fn decode_one(&self, codes: &[u8], out: &mut [f32]) {
        debug_assert!(codes.len() >= self.m);
        debug_assert_eq!(out.len(), self.dim);
        for mi in 0..self.m {
            let k = codes[mi] as usize;
            let c = &self.sub_centroids(mi)[k * self.dsub..(k + 1) * self.dsub];
            out[mi * self.dsub..(mi + 1) * self.dsub].copy_from_slice(c);
        }
    }

    /// Build the f32 ADC lookup table for `query`: `m × ksub`, entry
    /// `[mi][k] = ‖q_mi − c_mi,k‖²` (paper Eq. 2, extended from VQ to PQ).
    pub fn compute_luts(&self, query: &[f32]) -> Vec<f32> {
        let mut luts = Vec::new();
        self.compute_luts_into(query, &mut luts);
        luts
    }

    /// [`ProductQuantizer::compute_luts`] into a reusable buffer (cleared
    /// and resized; capacity kept across calls) — the executor's per-thread
    /// scratch path, allocation-free once the buffer has grown.
    pub fn compute_luts_into(&self, query: &[f32], luts: &mut Vec<f32>) {
        debug_assert_eq!(query.len(), self.dim);
        luts.clear();
        luts.resize(self.m * self.ksub, 0.0);
        for mi in 0..self.m {
            let qsub = &query[mi * self.dsub..(mi + 1) * self.dsub];
            let cents = self.sub_centroids(mi);
            for k in 0..self.ksub {
                luts[mi * self.ksub + k] =
                    crate::util::l2_sq(qsub, &cents[k * self.dsub..(k + 1) * self.dsub]);
            }
        }
    }

    /// [`ProductQuantizer::compute_luts`] for a whole query batch
    /// (`nq × dim` → `nq × m × ksub`, row-major) — the shape the
    /// coordinator's batch-level LUT reuse passes between indexes.
    pub fn compute_luts_batch(&self, queries: &[f32]) -> Vec<f32> {
        debug_assert_eq!(queries.len() % self.dim, 0);
        let mut out = Vec::with_capacity((queries.len() / self.dim) * self.m * self.ksub);
        for q in queries.chunks(self.dim) {
            out.extend(self.compute_luts(q));
        }
        out
    }

    /// Exact ADC distance of a coded vector given f32 LUTs (`m × ksub`).
    #[inline]
    pub fn adc_distance(&self, luts: &[f32], codes: &[u8]) -> f32 {
        let mut d = 0.0f32;
        for mi in 0..self.m {
            d += luts[mi * self.ksub + codes[mi] as usize];
        }
        d
    }

    /// Bytes per encoded vector before 4-bit packing.
    pub fn code_size(&self) -> usize {
        self.m
    }

    /// FNV-1a fingerprint over shape + codeword bits. Two quantizers with
    /// equal signatures produce identical `compute_luts` output for any
    /// query, so their LUTs are interchangeable — the coordinator's
    /// batch-level LUT-reuse contract ([`crate::index::Index::lut_signature`]).
    pub fn signature(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        eat(&(self.dim as u64).to_le_bytes());
        eat(&(self.m as u64).to_le_bytes());
        eat(&(self.ksub as u64).to_le_bytes());
        for &c in &self.centroids {
            eat(&c.to_bits().to_le_bytes());
        }
        h
    }
}

#[derive(Clone, Copy)]
struct CodesPtr(*mut u8);
unsafe impl Send for CodesPtr {}
unsafe impl Sync for CodesPtr {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_data(n: usize, dim: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n * dim).map(|_| rng.next_gaussian()).collect()
    }

    #[test]
    fn train_shapes() {
        let data = random_data(500, 32, 1);
        let pq = ProductQuantizer::train(&data, 32, &PqParams::new_4bit(8)).unwrap();
        assert_eq!(pq.dsub, 4);
        assert_eq!(pq.centroids.len(), 8 * 16 * 4);
        assert_eq!(pq.code_size(), 8);
    }

    #[test]
    fn encode_codes_in_range() {
        let data = random_data(300, 16, 2);
        let pq = ProductQuantizer::train(&data, 16, &PqParams::new_4bit(4)).unwrap();
        let codes = pq.encode(&data).unwrap();
        assert_eq!(codes.len(), 300 * 4);
        assert!(codes.iter().all(|&c| c < 16));
    }

    #[test]
    fn decode_reduces_error_vs_random() {
        // quantization error must be far below the error of a random vector
        let data = random_data(1000, 32, 3);
        let pq = ProductQuantizer::train(&data, 32, &PqParams::new_4bit(8)).unwrap();
        let codes = pq.encode(&data).unwrap();
        let mut rec = vec![0.0f32; 32];
        let mut err = 0.0f64;
        let mut base = 0.0f64;
        for i in 0..1000 {
            let x = &data[i * 32..(i + 1) * 32];
            pq.decode_one(&codes[i * 8..(i + 1) * 8], &mut rec);
            err += crate::util::l2_sq(x, &rec) as f64;
            base += x.iter().map(|v| v * v).sum::<f32>() as f64; // vs zero vector
        }
        assert!(err < base * 0.8, "err {err} base {base}");
    }

    #[test]
    fn adc_equals_decoded_distance() {
        // ADC(q, code) must equal ||q - decode(code)||² exactly (paper Eq. 3)
        let data = random_data(400, 24, 4);
        let pq = ProductQuantizer::train(&data, 24, &PqParams::new_4bit(6)).unwrap();
        let codes = pq.encode(&data).unwrap();
        let query = &data[..24];
        let luts = pq.compute_luts(query);
        let mut rec = vec![0.0f32; 24];
        for i in 0..50 {
            let c = &codes[i * 6..(i + 1) * 6];
            pq.decode_one(c, &mut rec);
            let direct = crate::util::l2_sq(query, &rec);
            let adc = pq.adc_distance(&luts, c);
            assert!((direct - adc).abs() < 1e-2 * (1.0 + direct), "i={i} {direct} vs {adc}");
        }
    }

    #[test]
    fn eight_bit_mode() {
        let data = random_data(600, 16, 5);
        let pq = ProductQuantizer::train(&data, 16, &PqParams::new_8bit(2)).unwrap();
        assert_eq!(pq.ksub, 256);
        let codes = pq.encode(&data[..160]).unwrap();
        assert_eq!(codes.len(), 10 * 2);
    }

    #[test]
    fn rejects_indivisible_dim() {
        let data = random_data(100, 30, 6);
        assert!(ProductQuantizer::train(&data, 30, &PqParams::new_4bit(8)).is_err());
    }

    #[test]
    fn rejects_tiny_training_set() {
        let data = random_data(8, 16, 7);
        assert!(ProductQuantizer::train(&data, 16, &PqParams::new_4bit(4)).is_err());
    }

    #[test]
    fn encode_is_nearest_codeword() {
        let data = random_data(200, 8, 8);
        let pq = ProductQuantizer::train(&data, 8, &PqParams::new_4bit(2)).unwrap();
        let mut codes = vec![0u8; 2];
        for i in 0..20 {
            let x = &data[i * 8..(i + 1) * 8];
            pq.encode_one(x, &mut codes);
            for mi in 0..2 {
                let sub = &x[mi * 4..(mi + 1) * 4];
                let cents = pq.sub_centroids(mi);
                let chosen = crate::util::l2_sq(sub, &cents[codes[mi] as usize * 4..][..4]);
                for k in 0..16 {
                    let d = crate::util::l2_sq(sub, &cents[k * 4..(k + 1) * 4]);
                    assert!(chosen <= d + 1e-5, "code {} not nearest", codes[mi]);
                }
            }
        }
    }

    #[test]
    fn nbits_helper() {
        assert_eq!(PqParams::new_4bit(8).nbits(), 4);
        assert_eq!(PqParams::new_8bit(8).nbits(), 8);
    }
}
