//! The baseline scan: asymmetric distance computation with an in-memory
//! f32 lookup table (paper Fig. 1a) — "original PQ" in Fig. 2.
//!
//! For each database code the distance is `Σ_m T[m][code_m]`, one main-
//! memory table lookup per sub-quantizer. This is exactly what the paper
//! accelerates: *"the table lookup … is not 'extremely' fast because (1) we
//! must use the main memory for the lookup, and (2) the entire operation
//! lacks concurrency"* (§2).

use crate::pq::codebook::ProductQuantizer;
use crate::util::topk::TopK;

/// One code row's ADC distance — the unrolled gather loop shared by every
/// scan below (shared so the float summation order, and therefore the
/// exact result, is identical between the filtered and unfiltered paths).
#[inline]
fn row_adc(luts: &[f32], ksub: usize, m: usize, c: &[u8]) -> f32 {
    // The inner loop is kept deliberately simple (indexed table gathers):
    // it IS the baseline whose memory-lookup latency the paper's kernel
    // removes. Unrolling m by 4 mirrors faiss's scalar scanner.
    let chunks = m / 4;
    let mut d0 = 0.0f32;
    let mut d1 = 0.0f32;
    let mut d2 = 0.0f32;
    let mut d3 = 0.0f32;
    for j in 0..chunks {
        let mi = j * 4;
        d0 += luts[mi * ksub + c[mi] as usize];
        d1 += luts[(mi + 1) * ksub + c[mi + 1] as usize];
        d2 += luts[(mi + 2) * ksub + c[mi + 2] as usize];
        d3 += luts[(mi + 3) * ksub + c[mi + 3] as usize];
    }
    let mut d = d0 + d1 + d2 + d3;
    for mi in chunks * 4..m {
        d += luts[mi * ksub + c[mi] as usize];
    }
    d
}

/// Scan all `n` codes (`n × m` bytes, one byte per sub-quantizer) against
/// f32 LUTs (`m × ksub`), returning the `k` nearest `(distances, labels)`.
///
/// `labels` maps scan position → external id (pass `None` for identity).
pub fn search_adc(
    pq: &ProductQuantizer,
    luts: &[f32],
    codes: &[u8],
    labels: Option<&[i64]>,
    k: usize,
) -> (Vec<f32>, Vec<i64>) {
    let m = pq.m;
    let ksub = pq.ksub;
    let n = codes.len() / m;
    let mut heap = TopK::new(k);
    for i in 0..n {
        let d = row_adc(luts, ksub, m, &codes[i * m..(i + 1) * m]);
        if d < heap.threshold() {
            let label = labels.map(|l| l[i]).unwrap_or(i as i64);
            heap.push(d, label);
        }
    }
    heap.into_sorted()
}

/// Filtered exact top-k: the `k` nearest among labels `keep` admits,
/// unpadded ascending `(distance, label)` pairs plus the admitted count
/// (for selectivity stats). Because the scan is exhaustive and the row sum
/// is shared with [`search_adc`], filtered results are *bit-identical* to
/// post-filtering an unfiltered scan.
pub fn topk_adc(
    pq: &ProductQuantizer,
    luts: &[f32],
    codes: &[u8],
    labels: Option<&[i64]>,
    k: usize,
    keep: Option<&dyn Fn(i64) -> bool>,
) -> (Vec<(f32, i64)>, usize) {
    let m = pq.m;
    let ksub = pq.ksub;
    let n = codes.len() / m;
    let mut kept = 0usize;
    if k == 0 {
        // still report selectivity so stats stay meaningful
        for i in 0..n {
            let label = labels.map(|l| l[i]).unwrap_or(i as i64);
            if keep.map(|f| f(label)).unwrap_or(true) {
                kept += 1;
            }
        }
        return (Vec::new(), kept);
    }
    let mut heap = TopK::new(k);
    for i in 0..n {
        let label = labels.map(|l| l[i]).unwrap_or(i as i64);
        if !keep.map(|f| f(label)).unwrap_or(true) {
            continue;
        }
        kept += 1;
        let d = row_adc(luts, ksub, m, &codes[i * m..(i + 1) * m]);
        if d < heap.threshold() {
            heap.push(d, label);
        }
    }
    (heap.into_hits(), kept)
}

/// Exact range scan: every `(distance, label)` with distance `<= radius`
/// among labels `keep` admits, ascending by `(distance, label)`, plus the
/// admitted count.
pub fn range_adc(
    pq: &ProductQuantizer,
    luts: &[f32],
    codes: &[u8],
    labels: Option<&[i64]>,
    radius: f32,
    keep: Option<&dyn Fn(i64) -> bool>,
) -> (Vec<(f32, i64)>, usize) {
    let m = pq.m;
    let ksub = pq.ksub;
    let n = codes.len() / m;
    let mut kept = 0usize;
    let mut hits = Vec::new();
    for i in 0..n {
        let label = labels.map(|l| l[i]).unwrap_or(i as i64);
        if !keep.map(|f| f(label)).unwrap_or(true) {
            continue;
        }
        kept += 1;
        let d = row_adc(luts, ksub, m, &codes[i * m..(i + 1) * m]);
        if d <= radius {
            hits.push((d, label));
        }
    }
    hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    (hits, kept)
}

/// Compute distances for *all* codes (used by tests and ground-truthing of
/// the quantized kernels; no top-k).
pub fn adc_distances_all(pq: &ProductQuantizer, luts: &[f32], codes: &[u8]) -> Vec<f32> {
    let m = pq.m;
    let n = codes.len() / m;
    (0..n).map(|i| pq.adc_distance(luts, &codes[i * m..(i + 1) * m])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::codebook::PqParams;
    use crate::util::rng::Rng;

    fn setup(n: usize, dim: usize, m: usize, seed: u64) -> (ProductQuantizer, Vec<f32>, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian()).collect();
        let pq = ProductQuantizer::train(&data, dim, &PqParams::new_4bit(m)).unwrap();
        let codes = pq.encode(&data).unwrap();
        (pq, data, codes)
    }

    #[test]
    fn finds_self_as_nearest_for_distinct_codes() {
        let (pq, data, codes) = setup(200, 16, 4, 11);
        // query = database vector 17; its own code must be at distance equal
        // to its quantization error, i.e. rank near the top.
        let q = &data[17 * 16..18 * 16];
        let luts = pq.compute_luts(q);
        let (dists, labels) = search_adc(&pq, &luts, &codes, None, 5);
        // vector 17's ADC distance:
        let self_d = pq.adc_distance(&luts, &codes[17 * 4..18 * 4]);
        assert!(dists[0] <= self_d + 1e-6);
        // and 17 (or a vector with an identical code) must appear in top-5
        let top_d_of_17_rank = dists.iter().position(|&d| (d - self_d).abs() < 1e-5);
        assert!(top_d_of_17_rank.is_some() || labels.contains(&17));
    }

    #[test]
    fn matches_exhaustive_sort() {
        let (pq, data, codes) = setup(500, 24, 6, 12);
        let q = &data[..24];
        let luts = pq.compute_luts(q);
        let all = adc_distances_all(&pq, &luts, &codes);
        let mut ranked: Vec<(f32, usize)> =
            all.iter().cloned().zip(0..).map(|(d, i)| (d, i)).collect();
        ranked.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let (dists, _labels) = search_adc(&pq, &luts, &codes, None, 10);
        for r in 0..10 {
            assert!((dists[r] - ranked[r].0).abs() < 1e-6, "rank {r}");
        }
    }

    #[test]
    fn labels_are_remapped() {
        let (pq, data, codes) = setup(100, 16, 4, 13);
        let q = &data[..16];
        let luts = pq.compute_luts(q);
        let ext: Vec<i64> = (0..100).map(|i| 1000 + i as i64).collect();
        let (_d, labels) = search_adc(&pq, &luts, &codes, Some(&ext), 3);
        assert!(labels.iter().all(|&l| (1000..1100).contains(&l)));
    }

    #[test]
    fn k_larger_than_n_pads() {
        let (pq, data, codes) = setup(20, 16, 4, 14);
        let luts = pq.compute_luts(&data[..16]);
        let (d, l) = search_adc(&pq, &luts, &codes, None, 50);
        assert_eq!(d.len(), 50);
        assert_eq!(l.iter().filter(|&&x| x == -1).count(), 30);
    }

    /// Filtered top-k must equal post-filtering the full distance array —
    /// bit-identical, since the row sum is shared.
    #[test]
    fn filtered_topk_matches_postfilter() {
        let (pq, data, codes) = setup(300, 16, 4, 16);
        let luts = pq.compute_luts(&data[..16]);
        let keep = |id: i64| id % 3 == 0;
        let (hits, kept) = topk_adc(&pq, &luts, &codes, None, 7, Some(&keep));
        assert_eq!(kept, 100);
        let all = adc_distances_all(&pq, &luts, &codes);
        let mut reference: Vec<(f32, i64)> = all
            .iter()
            .enumerate()
            .filter(|(i, _)| keep(*i as i64))
            .map(|(i, &d)| (d, i as i64))
            .collect();
        reference.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        reference.truncate(7);
        assert_eq!(hits.len(), 7);
        for (h, r) in hits.iter().zip(&reference) {
            assert!((h.0 - r.0).abs() < 1e-6);
        }
        // k == 0 still reports selectivity
        let (empty, kept0) = topk_adc(&pq, &luts, &codes, None, 0, Some(&keep));
        assert!(empty.is_empty());
        assert_eq!(kept0, 100);
    }

    #[test]
    fn range_adc_collects_exactly_within_radius() {
        let (pq, data, codes) = setup(250, 16, 4, 17);
        let luts = pq.compute_luts(&data[..16]);
        let all = adc_distances_all(&pq, &luts, &codes);
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let radius = sorted[25]; // ~10% of the database
        let (hits, kept) = range_adc(&pq, &luts, &codes, None, radius, None);
        assert_eq!(kept, 250);
        let want = all.iter().filter(|&&d| d <= radius).count();
        assert_eq!(hits.len(), want);
        assert!(hits.windows(2).all(|w| w[0].0 <= w[1].0));
        for &(d, l) in &hits {
            assert_eq!(d, all[l as usize]);
        }
    }

    #[test]
    fn odd_m_tail_handled() {
        // m=5 exercises the non-unrolled tail
        let (pq, data, codes) = setup(150, 20, 5, 15);
        let q = &data[..20];
        let luts = pq.compute_luts(q);
        let all = adc_distances_all(&pq, &luts, &codes);
        let (dists, labels) = search_adc(&pq, &luts, &codes, None, 1);
        let best = all.iter().cloned().fold(f32::INFINITY, f32::min);
        assert_eq!(dists[0], best);
        assert_eq!(all[labels[0] as usize], best);
    }
}
