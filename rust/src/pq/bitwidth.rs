//! The multi-bitwidth fastscan subsystem: 2-, 4- and 8-bit in-register ADC
//! on one dual-lane register model (Quick ADC / Quicker ADC, arXiv
//! 1704.07355 / 1812.09162, transplanted onto the paper's ARM kernel).
//!
//! The paper's 4-bit kernel is one point on the accuracy/speed curve. The
//! same 16-entry dual-table shuffle supports two more operating points, as
//! long as every width is expressed in shuffle-width (≤16-entry) tables:
//!
//! * **2-bit** (`K = 4`, faster/coarser): four codes fit one byte. Two
//!   adjacent sub-quantizers are *fused* into one 16-entry sum-table
//!   `T_fused[c₀ | c₁≪2] = T₀[c₀] + T₁[c₁]` — Quicker ADC's table-grouping
//!   idea — so a fused pair scans exactly like one 4-bit sub-quantizer:
//!   half the code bytes, half the shuffles of 4-bit at equal `M`.
//! * **4-bit** (`K = 16`): the paper's kernel, unchanged.
//! * **8-bit** (`K = 256` product-structured, slower/finer): each 8-bit
//!   sub-quantizer is the Cartesian product of two independent 4-bit
//!   quantizers over the two halves of its sub-space, so its 256-entry
//!   table is *separable*: `T[c] = T_lo[c & 0xF] + T_hi[c ≫ 4]`. The scan
//!   does paired low/high-nibble lookups against two 16-entry tables with
//!   the existing dual `pshufb`/`vqtbl1q_u8` shuffle — twice the work of
//!   4-bit at equal `M`, twice the code bits.
//!
//! Internally every width therefore reduces to a roster of 16-entry
//! **table rows** (fused rows for 2-bit, per-sub-quantizer rows for 4-bit,
//! lo/hi half-space rows for 8-bit) plus a [`LaneWiring`] telling the
//! kernel how a 32-byte code chunk's nibbles map onto the row pair —
//! see [`crate::pq::fastscan`]. [`CodeWidth`] carries that geometry;
//! [`build_width_luts`] turns per-query f32 tables into the
//! quantized+arranged kernel form; [`crate::pq::PackedCodes`] is the
//! matching width-parametric code layout.

use crate::pq::codebook::PqParams;
use crate::pq::fastscan::{KernelLuts, LaneWiring};
use crate::pq::lut::QuantizedLuts;
use crate::{Error, Result};

/// Bits per PQ code: the fastscan accuracy/speed axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CodeWidth {
    /// 2-bit codes, `K = 4` (Quicker ADC fused pairs): fastest, coarsest.
    W2,
    /// 4-bit codes, `K = 16`: the paper's kernel.
    W4,
    /// 8-bit codes, `K = 256` product-structured (paired nibble tables):
    /// slowest, finest.
    W8,
}

impl CodeWidth {
    pub const ALL: [CodeWidth; 3] = [CodeWidth::W2, CodeWidth::W4, CodeWidth::W8];

    /// Bits per code (2, 4, 8).
    #[inline]
    pub fn bits(self) -> usize {
        match self {
            CodeWidth::W2 => 2,
            CodeWidth::W4 => 4,
            CodeWidth::W8 => 8,
        }
    }

    /// Parse the factory-string suffix digit (`PQ16x{2,4,8}fs`).
    pub fn from_bits(bits: usize) -> Option<CodeWidth> {
        match bits {
            2 => Some(CodeWidth::W2),
            4 => Some(CodeWidth::W4),
            8 => Some(CodeWidth::W8),
            _ => None,
        }
    }

    /// Codewords per (user-facing) sub-quantizer: `2^bits`.
    #[inline]
    pub fn ksub(self) -> usize {
        1 << self.bits()
    }

    /// Codewords per *trained* sub-quantizer — the shuffle-width codebook
    /// the `ProductQuantizer` actually k-means: 4 for 2-bit, 16 otherwise
    /// (8-bit trains two 16-codeword halves per sub-quantizer).
    #[inline]
    pub fn sub_ksub(self) -> usize {
        match self {
            CodeWidth::W2 => 4,
            CodeWidth::W4 | CodeWidth::W8 => 16,
        }
    }

    /// Trained sub-quantizer count (= code columns [`crate::pq::PackedCodes`]
    /// packs and re-ranking reads) for `m` user-facing sub-quantizers:
    /// 8-bit splits each into a lo/hi half-space pair.
    #[inline]
    pub fn code_columns(self, m: usize) -> usize {
        match self {
            CodeWidth::W2 | CodeWidth::W4 => m,
            CodeWidth::W8 => 2 * m,
        }
    }

    /// 32-byte code chunks (= dual-table registers) per 32-vector block.
    /// Each chunk covers two 16-entry table rows.
    #[inline]
    pub fn chunks(self, m: usize) -> usize {
        match self {
            // fused pairs, then fused rows grouped two per chunk
            CodeWidth::W2 => m.div_ceil(2).div_ceil(2),
            CodeWidth::W4 => m.div_ceil(2),
            CodeWidth::W8 => m,
        }
    }

    /// 16-entry table rows the kernel consumes (chunk count × 2, phantom
    /// rows zero-padded).
    #[inline]
    pub fn lut_rows(self, m: usize) -> usize {
        2 * self.chunks(m)
    }

    /// How a chunk's nibbles address the chunk's two table rows.
    #[inline]
    pub fn wiring(self) -> LaneWiring {
        match self {
            CodeWidth::W2 | CodeWidth::W4 => LaneWiring::PairedTables,
            CodeWidth::W8 => LaneWiring::SplitNibble,
        }
    }

    /// Training parameters for the internal [`crate::pq::ProductQuantizer`].
    pub fn pq_params(self, m: usize) -> PqParams {
        let mut p = PqParams::new_4bit(self.code_columns(m));
        p.ksub = self.sub_ksub();
        p
    }

    /// Check `dim`/`m` are compatible with this width before training, with
    /// a width-specific message (8-bit needs `dim % 2m == 0` because each
    /// sub-space is split into two quantized halves).
    pub fn validate(self, dim: usize, m: usize) -> Result<()> {
        let cols = self.code_columns(m);
        if m == 0 || cols == 0 || dim % cols != 0 {
            return Err(Error::InvalidParameter(match self {
                CodeWidth::W8 => format!(
                    "8-bit fastscan splits each sub-quantizer into nibble halves: \
                     dim {dim} must be divisible by 2*m = {cols}"
                ),
                _ => format!("dim {dim} not divisible by m {m}"),
            }));
        }
        Ok(())
    }

    /// Stable name used by CLI flags / bench tables ("2", "4", "8").
    pub fn name(self) -> &'static str {
        match self {
            CodeWidth::W2 => "2",
            CodeWidth::W4 => "4",
            CodeWidth::W8 => "8",
        }
    }
}

impl std::fmt::Display for CodeWidth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}-bit", self.bits())
    }
}

/// A query's scan tables in both forms the search path needs: the affine
/// decode parameters ([`QuantizedLuts`], rows already fused/split per
/// width) and the kernel-arranged dual-table bytes ([`KernelLuts`]).
pub struct WidthLuts {
    pub qluts: QuantizedLuts,
    pub kernel: KernelLuts,
}

impl WidthLuts {
    /// Hand the table buffers back to a [`WidthLutsBuf`] so the next
    /// [`build_width_luts_with`] call reuses them instead of allocating.
    pub fn recycle(self, buf: &mut WidthLutsBuf) {
        buf.qlut_data = self.qluts.data;
        buf.kernel_bytes = self.kernel.bytes;
    }
}

/// Reusable backing storage for [`build_width_luts_with`] — one per
/// scratch arena. Buffers are taken for the lifetime of a [`WidthLuts`]
/// and returned by [`WidthLuts::recycle`]; grown, never shrunk, so a
/// warmed-up arena builds per-query tables with zero heap allocations.
#[derive(Debug, Default)]
pub struct WidthLutsBuf {
    /// 2-bit fused-row staging (`m.div_ceil(2) × 16` f32).
    fused: Vec<f32>,
    /// [`QuantizedLuts::data`] backing.
    qlut_data: Vec<u8>,
    /// [`KernelLuts`] `bytes` backing.
    kernel_bytes: Vec<u8>,
}

impl WidthLutsBuf {
    /// Bytes currently reserved across the buffers (capacity accounting
    /// for the executor's scratch high-water metric).
    pub fn reserved_bytes(&self) -> usize {
        self.fused.capacity() * std::mem::size_of::<f32>()
            + self.qlut_data.capacity()
            + self.kernel_bytes.capacity()
    }
}

/// Quantize + arrange per-query f32 tables for a width's kernel.
///
/// `luts_f32` is the internal quantizer's table, `code_columns(m) ×
/// sub_ksub` (i.e. exactly `ProductQuantizer::compute_luts` of the PQ that
/// [`CodeWidth::pq_params`] trained):
///
/// * 2-bit: adjacent 4-entry rows are fused into 16-entry sum-tables
///   *before* u8 quantization, so the fused rows use the full byte range.
/// * 4-bit: rows pass through (the existing path).
/// * 8-bit: the `2m` half-space rows map one-to-one onto lo/hi table rows.
pub fn build_width_luts(luts_f32: &[f32], m: usize, width: CodeWidth) -> WidthLuts {
    build_width_luts_with(luts_f32, m, width, &mut WidthLutsBuf::default())
}

/// [`build_width_luts`] on recycled [`WidthLutsBuf`] storage — the
/// executor's per-thread scratch path. Bit-identical output; zero heap
/// allocations once the buffers have grown to the index's table shape.
pub fn build_width_luts_with(
    luts_f32: &[f32],
    m: usize,
    width: CodeWidth,
    buf: &mut WidthLutsBuf,
) -> WidthLuts {
    let cols = width.code_columns(m);
    let sub_ksub = width.sub_ksub();
    debug_assert_eq!(luts_f32.len(), cols * sub_ksub, "luts shape vs width");
    let qlut_data = std::mem::take(&mut buf.qlut_data);
    let qluts = match width {
        CodeWidth::W2 => {
            fuse_2bit_rows_into(luts_f32, m, &mut buf.fused);
            QuantizedLuts::from_f32_reuse(&buf.fused, m.div_ceil(2), 16, qlut_data)
        }
        CodeWidth::W4 | CodeWidth::W8 => {
            QuantizedLuts::from_f32_reuse(luts_f32, cols, 16, qlut_data)
        }
    };
    let kernel = KernelLuts::build_wired_reuse(
        &qluts,
        width.lut_rows(m),
        width.wiring(),
        std::mem::take(&mut buf.kernel_bytes),
    );
    WidthLuts { qluts, kernel }
}

/// Fuse adjacent 2-bit (4-entry) f32 rows into 16-entry sum-tables:
/// `fused[p][c₀ | c₁≪2] = row(2p)[c₀] + row(2p+1)[c₁]`. An odd trailing
/// sub-quantizer fuses with a phantom all-zero partner (its `c₁` index is
/// always 0 at scan time, so the duplicated entries are never addressed).
fn fuse_2bit_rows(luts_f32: &[f32], m: usize) -> Vec<f32> {
    let mut fused = Vec::new();
    fuse_2bit_rows_into(luts_f32, m, &mut fused);
    fused
}

/// [`fuse_2bit_rows`] into a reusable buffer (cleared and resized).
fn fuse_2bit_rows_into(luts_f32: &[f32], m: usize, fused: &mut Vec<f32>) {
    let nfused = m.div_ceil(2);
    fused.clear();
    fused.resize(nfused * 16, 0.0);
    for p in 0..nfused {
        let a = &luts_f32[(2 * p) * 4..(2 * p) * 4 + 4];
        for i in 0..16 {
            let hi = if 2 * p + 1 < m { luts_f32[(2 * p + 1) * 4 + (i >> 2)] } else { 0.0 };
            fused[p * 16 + i] = a[i & 3] + hi;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn geometry_per_width() {
        // (width, m) → (code_columns, chunks, lut_rows)
        for (w, m, cols, chunks) in [
            (CodeWidth::W2, 16, 16, 4),
            (CodeWidth::W2, 5, 5, 2), // 3 fused rows → 2 chunks
            (CodeWidth::W2, 1, 1, 1),
            (CodeWidth::W4, 16, 16, 8),
            (CodeWidth::W4, 3, 3, 2),
            (CodeWidth::W8, 16, 32, 16),
            (CodeWidth::W8, 1, 2, 1),
        ] {
            assert_eq!(w.code_columns(m), cols, "{w} m={m}");
            assert_eq!(w.chunks(m), chunks, "{w} m={m}");
            assert_eq!(w.lut_rows(m), 2 * chunks, "{w} m={m}");
        }
    }

    #[test]
    fn bits_name_roundtrip() {
        for w in CodeWidth::ALL {
            assert_eq!(CodeWidth::from_bits(w.bits()), Some(w));
            assert_eq!(w.name(), w.bits().to_string());
            assert_eq!(w.ksub(), 1 << w.bits());
        }
        assert_eq!(CodeWidth::from_bits(3), None);
        assert_eq!(CodeWidth::from_bits(16), None);
    }

    #[test]
    fn validate_messages() {
        assert!(CodeWidth::W4.validate(64, 16).is_ok());
        assert!(CodeWidth::W2.validate(64, 16).is_ok());
        assert!(CodeWidth::W8.validate(64, 32).is_ok());
        // dim 64 % (2*24) != 0 — the 8-bit message must name the 2m rule
        let e = CodeWidth::W8.validate(64, 24).unwrap_err().to_string();
        assert!(e.contains("2*m"), "{e}");
        assert!(CodeWidth::W4.validate(10, 3).is_err());
        assert!(CodeWidth::W4.validate(10, 0).is_err());
    }

    #[test]
    fn fused_rows_are_exact_sums() {
        let mut rng = Rng::new(71);
        let m = 7; // odd: last row fuses with a phantom partner
        let luts: Vec<f32> = (0..m * 4).map(|_| rng.next_f32() * 5.0).collect();
        let fused = fuse_2bit_rows(&luts, m);
        assert_eq!(fused.len(), 4 * 16);
        for p in 0..3 {
            for c0 in 0..4 {
                for c1 in 0..4 {
                    let want = luts[2 * p * 4 + c0] + luts[(2 * p + 1) * 4 + c1];
                    assert_eq!(fused[p * 16 + (c0 | (c1 << 2))], want);
                }
            }
        }
        // phantom partner: index c1 = 0 plane equals the lone row
        for c0 in 0..4 {
            assert_eq!(fused[3 * 16 + c0], luts[6 * 4 + c0]);
        }
    }

    #[test]
    fn width_luts_decode_matches_f32_sum() {
        // For every width: quantize random f32 tables, accumulate a random
        // code assignment through the kernel rows, decode, and compare with
        // the exact f32 sum within the quantization error bound.
        let mut rng = Rng::new(72);
        for width in CodeWidth::ALL {
            let m = 8;
            let cols = width.code_columns(m);
            let sub_ksub = width.sub_ksub();
            let luts: Vec<f32> =
                (0..cols * sub_ksub).map(|_| rng.next_f32() * 7.0 + 1.0).collect();
            let wl = build_width_luts(&luts, m, width);
            for _ in 0..50 {
                let codes: Vec<usize> = (0..cols).map(|_| rng.below(sub_ksub)).collect();
                let exact: f32 = (0..cols).map(|c| luts[c * sub_ksub + codes[c]]).sum();
                // accumulate via the width's table rows
                let acc: u16 = match width {
                    CodeWidth::W2 => (0..m.div_ceil(2))
                        .map(|p| {
                            let c1 = if 2 * p + 1 < m { codes[2 * p + 1] } else { 0 };
                            wl.qluts.row(p)[codes[2 * p] | (c1 << 2)] as u16
                        })
                        .sum(),
                    _ => (0..cols).map(|c| wl.qluts.row(c)[codes[c]] as u16).sum(),
                };
                let approx = wl.qluts.decode(acc);
                assert!(
                    (exact - approx).abs() <= wl.qluts.max_abs_error() + 1e-3,
                    "{width}: exact {exact} approx {approx}"
                );
            }
        }
    }

    #[test]
    fn kernel_rows_padded_with_zeros() {
        let mut rng = Rng::new(73);
        let m = 3; // W2: 2 fused rows → 1 chunk... div_ceil(2)=2 rows, pad to 2
        let luts: Vec<f32> = (0..m * 4).map(|_| rng.next_f32()).collect();
        let wl = build_width_luts(&luts, m, CodeWidth::W2);
        assert_eq!(wl.kernel.lut_rows, CodeWidth::W2.lut_rows(m));
        assert_eq!(wl.kernel.bytes.len(), wl.kernel.lut_rows * 16);
    }
}
