//! The 4-bit PQ fastscan kernel — the paper's §3, end to end.
//!
//! Per 32-vector block and per sub-quantizer pair `(q, q+1)`:
//!
//! 1. one 32-byte load of packed codes (virtual 256-bit register),
//! 2. nibble extraction (`& 0x0F`, `>> 4`),
//! 3. **dual-table shuffle** — the 256-bit `_mm256_shuffle_epi8` emulated
//!    as two 128-bit `vqtbl1q_u8`, lane-lo against `T_q`, lane-hi against
//!    `T_{q+1}` (Fig. 1c),
//! 4. zero-extend and saturating-accumulate into u16 lanes.
//!
//! After the pair loop, 32 quantized distances are compared against the
//! current reservoir threshold with a SIMD compare + emulated `movemask`
//! (the AVX2-only instruction the paper re-creates), and only surviving
//! lanes touch the reservoir. Candidates are optionally re-ranked with the
//! exact f32 tables.
//!
//! Two differential-tested implementations: the portable NEON-semantics
//! model ([`crate::simd`]) and a real-SIMD SSSE3 path
//! ([`crate::simd::x86`]).

use crate::pq::codebook::ProductQuantizer;
use crate::pq::layout::PackedCodes4;
use crate::pq::lut::QuantizedLuts;
use crate::pq::BLOCK_SIZE;
use crate::simd::{best_backend, Backend, Simd256u16, Simd256u8};
use crate::util::topk::{TopK, U16Reservoir};

/// Fastscan search options.
#[derive(Clone, Debug)]
pub struct FastScanParams {
    /// Which kernel implementation to run.
    pub backend: Backend,
    /// Re-rank reservoir candidates with exact f32 tables (default true —
    /// recovers "same accuracy" as original PQ, paper Fig. 2).
    pub rerank: bool,
    /// Reservoir over-collection factor relative to k.
    pub reservoir_factor: usize,
}

impl Default for FastScanParams {
    fn default() -> Self {
        Self { backend: best_backend(), rerank: true, reservoir_factor: 8 }
    }
}

/// LUTs padded/arranged for the kernel: `m_pad × 16` bytes, so the pair
/// `(2p, 2p+1)` is one contiguous 32-byte dual-table register.
pub struct KernelLuts {
    pub bytes: Vec<u8>,
    pub m_pad: usize,
}

impl KernelLuts {
    pub fn build(qluts: &QuantizedLuts, m_pad: usize) -> Self {
        assert_eq!(qluts.ksub, 16, "fastscan requires ksub=16 (4-bit codes)");
        let mut bytes = vec![0u8; m_pad * 16];
        for mi in 0..qluts.m {
            bytes[mi * 16..(mi + 1) * 16].copy_from_slice(qluts.row(mi));
        }
        // phantom sub-quantizer rows (odd-M padding) stay all-zero: they
        // contribute nothing to any distance.
        Self { bytes, m_pad }
    }

    #[inline]
    pub fn pair(&self, p: usize) -> &[u8] {
        &self.bytes[p * 32..(p + 1) * 32]
    }
}

// ------------------------------------------------------------------ kernels

/// Portable (NEON-semantics) block kernel: 32 quantized distances.
#[inline]
pub fn accumulate_block_portable(block: &[u8], luts: &KernelLuts, out: &mut [u16; BLOCK_SIZE]) {
    let npairs = luts.m_pad / 2;
    let mask = Simd256u8::splat(0x0F);
    let mut acc_a = Simd256u16::zero(); // vectors 0..16
    let mut acc_b = Simd256u16::zero(); // vectors 16..32
    for p in 0..npairs {
        let c = Simd256u8::load(&block[p * 32..(p + 1) * 32]);
        let clo = c.and(mask); // codes of (q, q+1) for v0..v15
        let chi = c.shr4(); // codes of (q, q+1) for v16..v31 (already < 16)
        let tables = Simd256u8::load(luts.pair(p)); // lane-lo: T_q, lane-hi: T_{q+1}
        let r0 = Simd256u8::shuffle_dual(tables, clo);
        let r1 = Simd256u8::shuffle_dual(tables, chi);
        let (w00, w01) = r0.widen(); // contrib of q / q+1 for v0..15
        acc_a = acc_a.sat_add(w00).sat_add(w01);
        let (w10, w11) = r1.widen();
        acc_b = acc_b.sat_add(w10).sat_add(w11);
    }
    acc_a.store(&mut out[..16]);
    acc_b.store(&mut out[16..]);
}

/// Real-SIMD SSSE3 block kernel (x86_64). Same structure, `pshufb` lanes.
///
/// # Safety
/// Caller must ensure SSSE3 is available ([`best_backend`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
pub unsafe fn accumulate_block_ssse3(block: &[u8], luts: &KernelLuts, out: &mut [u16; BLOCK_SIZE]) {
    use crate::simd::x86::{X86Simd256u16, X86Simd256u8};
    let npairs = luts.m_pad / 2;
    let mask = X86Simd256u8::splat(0x0F);
    let mut acc_a = X86Simd256u16::zero();
    let mut acc_b = X86Simd256u16::zero();
    for p in 0..npairs {
        let c = X86Simd256u8::load(block.as_ptr().add(p * 32));
        let clo = c.and(mask);
        let chi = c.shr4(); // includes the &0x0F internally
        let tables = X86Simd256u8::load(luts.bytes.as_ptr().add(p * 32));
        let r0 = X86Simd256u8::shuffle_dual(tables, clo);
        let r1 = X86Simd256u8::shuffle_dual(tables, chi);
        let (w00, w01) = r0.widen();
        acc_a = acc_a.sat_add(w00).sat_add(w01);
        let (w10, w11) = r1.widen();
        acc_b = acc_b.sat_add(w10).sat_add(w11);
    }
    acc_a.store(out.as_mut_ptr());
    acc_b.store(out.as_mut_ptr().add(16));
}

/// Dispatch one block through the chosen backend.
#[inline]
fn accumulate_block(
    backend: Backend,
    block: &[u8],
    luts: &KernelLuts,
    out: &mut [u16; BLOCK_SIZE],
) {
    match backend {
        Backend::Portable => accumulate_block_portable(block, luts, out),
        #[cfg(target_arch = "x86_64")]
        Backend::Ssse3 => unsafe { accumulate_block_ssse3(block, luts, out) },
        #[cfg(not(target_arch = "x86_64"))]
        Backend::Ssse3 => accumulate_block_portable(block, luts, out),
    }
}

/// All quantized distances (n entries) — tests, ablations, IVF internals.
pub fn fastscan_distances_all(
    packed: &PackedCodes4,
    luts: &KernelLuts,
    backend: Backend,
) -> Vec<u16> {
    let mut out = vec![0u16; packed.n];
    let mut block_d = [0u16; BLOCK_SIZE];
    let bb = packed.block_bytes();
    for b in 0..packed.nblocks() {
        accumulate_block(backend, &packed.data[b * bb..(b + 1) * bb], luts, &mut block_d);
        let base = b * BLOCK_SIZE;
        let take = BLOCK_SIZE.min(packed.n - base);
        out[base..base + take].copy_from_slice(&block_d[..take]);
    }
    out
}

/// Scan all blocks into a reservoir, SIMD-pruning lanes above the current
/// threshold via compare + emulated movemask.
pub fn scan_into_reservoir(
    packed: &PackedCodes4,
    luts: &KernelLuts,
    backend: Backend,
    labels: Option<&[i64]>,
    reservoir: &mut U16Reservoir,
) {
    #[cfg(target_arch = "x86_64")]
    if backend == Backend::Ssse3 {
        // fused hot path: tables hoisted into registers, in-register
        // threshold compare, stores only for surviving blocks
        unsafe { scan_reservoir_ssse3(packed, luts, labels, reservoir) };
        return;
    }
    scan_reservoir_portable(packed, luts, labels, reservoir);
}

fn scan_reservoir_portable(
    packed: &PackedCodes4,
    luts: &KernelLuts,
    labels: Option<&[i64]>,
    reservoir: &mut U16Reservoir,
) {
    let mut block_d = [0u16; BLOCK_SIZE];
    let bb = packed.block_bytes();
    let nblocks = packed.nblocks();
    for b in 0..nblocks {
        accumulate_block_portable(&packed.data[b * bb..(b + 1) * bb], luts, &mut block_d);
        let base = b * BLOCK_SIZE;
        let limit = BLOCK_SIZE.min(packed.n - base);
        let thr = reservoir.threshold();

        // SIMD threshold test: two Simd256u16 lane groups → 32-bit mask.
        let thr_v = Simd256u16::splat(thr);
        let lo = Simd256u16 {
            lo: crate::simd::U16x8(block_d[0..8].try_into().unwrap()),
            hi: crate::simd::U16x8(block_d[8..16].try_into().unwrap()),
        };
        let hi = Simd256u16 {
            lo: crate::simd::U16x8(block_d[16..24].try_into().unwrap()),
            hi: crate::simd::U16x8(block_d[24..32].try_into().unwrap()),
        };
        let mut mask = (lo.lt(thr_v).movemask() as u32) | ((hi.lt(thr_v).movemask() as u32) << 16);
        if limit < BLOCK_SIZE {
            mask &= (1u32 << limit) - 1; // drop phantom padding lanes
        }
        while mask != 0 {
            let v = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let idx = base + v;
            let label = labels.map(|l| l[idx]).unwrap_or(idx as i64);
            reservoir.push(block_d[v], label);
        }
    }
}

/// Fused SSSE3 scan (the §Perf hot path):
///
/// * the `m_pad/2` dual-table registers are loaded **once** and stay in
///   registers across all blocks (the paper's register-resident tables,
///   taken to its limit),
/// * the reservoir threshold test happens **in-register** on the u16
///   accumulators (`subs_epu16` + `cmpeq` + `packs` + `movemask` — the
///   unsigned-compare idiom, since SSE2 lacks `cmplt_epu16`),
/// * distances are stored to memory only when some lane survives, which is
///   rare once the threshold tightens.
///
/// # Safety
/// Caller must ensure SSSE3 is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
unsafe fn scan_reservoir_ssse3(
    packed: &PackedCodes4,
    luts: &KernelLuts,
    labels: Option<&[i64]>,
    reservoir: &mut U16Reservoir,
) {
    #![allow(unsafe_op_in_unsafe_fn)]
    use core::arch::x86_64::*;
    const MAX_PAIRS: usize = 128;
    let npairs = luts.m_pad / 2;
    assert!(npairs <= MAX_PAIRS, "M too large for the fused kernel");

    // hoist the dual-table registers out of the block loop
    let mut tables = [unsafe { _mm_setzero_si128() }; MAX_PAIRS * 2];
    for p in 0..npairs {
        let ptr = luts.bytes.as_ptr().add(p * 32);
        tables[2 * p] = _mm_loadu_si128(ptr as *const __m128i);
        tables[2 * p + 1] = _mm_loadu_si128(ptr.add(16) as *const __m128i);
    }
    let nib = _mm_set1_epi8(0x0F);
    let zero = _mm_setzero_si128();

    let bb = packed.block_bytes();
    let nblocks = packed.nblocks();
    let data = packed.data.as_ptr();
    let mut block_d = [0u16; BLOCK_SIZE];

    for b in 0..nblocks {
        let base_ptr = data.add(b * bb);
        // accumulators: 4 × 8 u16 lanes covering vectors 0..32
        let mut a0 = zero; // v0..8
        let mut a1 = zero; // v8..16
        let mut a2 = zero; // v16..24
        let mut a3 = zero; // v24..32
        for p in 0..npairs {
            let c_lo = _mm_loadu_si128(base_ptr.add(p * 32) as *const __m128i);
            let c_hi = _mm_loadu_si128(base_ptr.add(p * 32 + 16) as *const __m128i);
            let t_lo = tables[2 * p];
            let t_hi = tables[2 * p + 1];
            // v0..16 contributions of sub-quantizers (q, q+1)
            let r0_lo = _mm_shuffle_epi8(t_lo, _mm_and_si128(c_lo, nib));
            let r0_hi = _mm_shuffle_epi8(t_hi, _mm_and_si128(c_hi, nib));
            // v16..32 contributions
            let r1_lo = _mm_shuffle_epi8(t_lo, _mm_and_si128(_mm_srli_epi16(c_lo, 4), nib));
            let r1_hi = _mm_shuffle_epi8(t_hi, _mm_and_si128(_mm_srli_epi16(c_hi, 4), nib));
            // widen + saturating accumulate (both lane groups feed the
            // same vectors — the faiss "fixup" merged into the add chain)
            a0 = _mm_adds_epu16(a0, _mm_unpacklo_epi8(r0_lo, zero));
            a1 = _mm_adds_epu16(a1, _mm_unpackhi_epi8(r0_lo, zero));
            a0 = _mm_adds_epu16(a0, _mm_unpacklo_epi8(r0_hi, zero));
            a1 = _mm_adds_epu16(a1, _mm_unpackhi_epi8(r0_hi, zero));
            a2 = _mm_adds_epu16(a2, _mm_unpacklo_epi8(r1_lo, zero));
            a3 = _mm_adds_epu16(a3, _mm_unpackhi_epi8(r1_lo, zero));
            a2 = _mm_adds_epu16(a2, _mm_unpacklo_epi8(r1_hi, zero));
            a3 = _mm_adds_epu16(a3, _mm_unpackhi_epi8(r1_hi, zero));
        }
        // in-register threshold: acc < thr ⟺ subs_epu16(acc, thr-1) == 0
        let thr = reservoir.threshold();
        if thr == 0 {
            continue;
        }
        let thr_m1 = _mm_set1_epi16(thr.wrapping_sub(1) as i16);
        let c0 = _mm_cmpeq_epi16(_mm_subs_epu16(a0, thr_m1), zero);
        let c1 = _mm_cmpeq_epi16(_mm_subs_epu16(a1, thr_m1), zero);
        let c2 = _mm_cmpeq_epi16(_mm_subs_epu16(a2, thr_m1), zero);
        let c3 = _mm_cmpeq_epi16(_mm_subs_epu16(a3, thr_m1), zero);
        let mask_lo = _mm_movemask_epi8(_mm_packs_epi16(c0, c1)) as u32;
        let mask_hi = _mm_movemask_epi8(_mm_packs_epi16(c2, c3)) as u32;
        let mut mask = mask_lo | (mask_hi << 16);
        if mask == 0 {
            continue; // common case once the threshold tightens: no stores
        }
        let base = b * BLOCK_SIZE;
        let limit = BLOCK_SIZE.min(packed.n - base);
        if limit < BLOCK_SIZE {
            mask &= (1u32 << limit) - 1;
        }
        _mm_storeu_si128(block_d.as_mut_ptr() as *mut __m128i, a0);
        _mm_storeu_si128(block_d.as_mut_ptr().add(8) as *mut __m128i, a1);
        _mm_storeu_si128(block_d.as_mut_ptr().add(16) as *mut __m128i, a2);
        _mm_storeu_si128(block_d.as_mut_ptr().add(24) as *mut __m128i, a3);
        while mask != 0 {
            let v = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let idx = base + v;
            let label = labels.map(|l| l[idx]).unwrap_or(idx as i64);
            reservoir.push(block_d[v], label);
        }
    }
}

/// Full 4-bit PQ search: build LUTs from `query`, quantize, scan, re-rank.
///
/// `labels` maps scan position → external id (identity if `None`).
pub fn search_fastscan(
    pq: &ProductQuantizer,
    packed: &PackedCodes4,
    query: &[f32],
    k: usize,
    params: &FastScanParams,
    labels: Option<&[i64]>,
) -> (Vec<f32>, Vec<i64>) {
    let luts_f32 = pq.compute_luts(query);
    search_fastscan_with_luts(pq, packed, &luts_f32, k, params, labels)
}

/// Same as [`search_fastscan`] but with precomputed f32 LUTs (`m × ksub`) —
/// the IVF path reuses one LUT set across probed lists.
pub fn search_fastscan_with_luts(
    pq: &ProductQuantizer,
    packed: &PackedCodes4,
    luts_f32: &[f32],
    k: usize,
    params: &FastScanParams,
    labels: Option<&[i64]>,
) -> (Vec<f32>, Vec<i64>) {
    let qluts = QuantizedLuts::from_f32(luts_f32, pq.m, pq.ksub);
    let kluts = KernelLuts::build(&qluts, packed.m_pad);
    let mut reservoir = U16Reservoir::new(k, params.reservoir_factor);
    scan_into_reservoir(packed, &kluts, params.backend, labels, &mut reservoir);
    let cands = reservoir.into_candidates();

    let mut heap = TopK::new(k);
    if params.rerank {
        // exact ADC on the survivors — needs scan positions, so build a
        // reverse map when labels were remapped.
        let mut codes_buf = vec![0u8; pq.m];
        match labels {
            None => {
                for (_, pos) in cands {
                    let i = pos as usize;
                    for q in 0..pq.m {
                        codes_buf[q] = packed.code_at(i, q);
                    }
                    heap.push(pq.adc_distance(luts_f32, &codes_buf), pos);
                }
            }
            Some(ls) => {
                // label -> position lookup by scanning is O(n); instead keep
                // positions: reservoir stored external labels, so recover
                // positions by hashing the label array once.
                let mut pos_of = std::collections::HashMap::with_capacity(ls.len());
                for (i, &l) in ls.iter().enumerate() {
                    pos_of.insert(l, i);
                }
                for (_, label) in cands {
                    let i = pos_of[&label];
                    for q in 0..pq.m {
                        codes_buf[q] = packed.code_at(i, q);
                    }
                    heap.push(pq.adc_distance(luts_f32, &codes_buf), label);
                }
            }
        }
    } else {
        for (d16, label) in cands {
            heap.push(qluts.decode(d16), label);
        }
    }
    heap.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::adc::{adc_distances_all, search_adc};
    use crate::pq::codebook::PqParams;
    use crate::simd::available_backends;
    use crate::util::rng::Rng;

    fn setup(n: usize, dim: usize, m: usize, seed: u64) -> (ProductQuantizer, Vec<f32>, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian()).collect();
        let pq = ProductQuantizer::train(&data, dim, &PqParams::new_4bit(m)).unwrap();
        let codes = pq.encode(&data).unwrap();
        (pq, data, codes)
    }

    /// The central correctness property: the SIMD kernel's quantized
    /// distances equal the scalar sum of quantized table entries, for every
    /// backend, including odd M and partial blocks.
    #[test]
    fn kernel_matches_scalar_quantized_sum() {
        let mut rng = Rng::new(31);
        for &(n, m) in &[(32usize, 2usize), (100, 8), (33, 16), (64, 5), (7, 3), (256, 32)] {
            let codes: Vec<u8> = (0..n * m).map(|_| (rng.next_u32() % 16) as u8).collect();
            let luts_f32: Vec<f32> = (0..m * 16).map(|_| rng.next_f32() * 9.0).collect();
            let qluts = QuantizedLuts::from_f32(&luts_f32, m, 16);
            let packed = PackedCodes4::pack(&codes, m).unwrap();
            let kluts = KernelLuts::build(&qluts, packed.m_pad);
            for backend in available_backends() {
                let got = fastscan_distances_all(&packed, &kluts, backend);
                for i in 0..n {
                    let expect: u16 = (0..m)
                        .map(|q| qluts.row(q)[codes[i * m + q] as usize] as u16)
                        .sum();
                    assert_eq!(got[i], expect, "n={n} m={m} i={i} {backend:?}");
                }
            }
        }
    }

    #[test]
    fn backends_agree_exactly() {
        let backends = available_backends();
        if backends.len() < 2 {
            eprintln!("single backend host; skipping cross-check");
            return;
        }
        let mut rng = Rng::new(32);
        for _ in 0..20 {
            let n = 1 + rng.below(200);
            let m = 2 * (1 + rng.below(16));
            let codes: Vec<u8> = (0..n * m).map(|_| (rng.next_u32() % 16) as u8).collect();
            let luts_f32: Vec<f32> = (0..m * 16).map(|_| rng.next_f32() * 5.0).collect();
            let qluts = QuantizedLuts::from_f32(&luts_f32, m, 16);
            let packed = PackedCodes4::pack(&codes, m).unwrap();
            let kluts = KernelLuts::build(&qluts, packed.m_pad);
            let a = fastscan_distances_all(&packed, &kluts, backends[0]);
            let b = fastscan_distances_all(&packed, &kluts, backends[1]);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn reservoir_scan_matches_full_distances() {
        let (pq, data, codes) = setup(300, 32, 8, 33);
        let packed = PackedCodes4::pack(&codes, 8).unwrap();
        let luts_f32 = pq.compute_luts(&data[..32]);
        let qluts = QuantizedLuts::from_f32(&luts_f32, 8, 16);
        let kluts = KernelLuts::build(&qluts, packed.m_pad);
        for backend in available_backends() {
            let all = fastscan_distances_all(&packed, &kluts, backend);
            let mut res = U16Reservoir::new(5, 4);
            scan_into_reservoir(&packed, &kluts, backend, None, &mut res);
            let cands = res.into_candidates();
            let mut sorted = all.clone();
            sorted.sort_unstable();
            let kth = sorted[4];
            for (i, &d) in all.iter().enumerate() {
                if d < kth {
                    assert!(
                        cands.iter().any(|&(cd, cl)| cl == i as i64 && cd == d),
                        "missing strict candidate {i} ({backend:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn reranked_search_matches_adc_baseline() {
        // Paper Fig. 2: 4-bit PQ achieves the *same accuracy* as original
        // PQ (same K=16 codes). With re-ranking the results must agree on
        // distances (labels may differ on exact ties).
        let (pq, data, codes) = setup(500, 32, 16, 34);
        let packed = PackedCodes4::pack(&codes, 16).unwrap();
        for qi in 0..10 {
            let q = &data[qi * 32..(qi + 1) * 32];
            let luts = pq.compute_luts(q);
            let (d_base, _l_base) = search_adc(&pq, &luts, &codes, None, 10);
            let (d_fast, _l_fast) = search_fastscan(
                &pq,
                &packed,
                q,
                10,
                &FastScanParams::default(),
                None,
            );
            for r in 0..10 {
                assert!(
                    (d_base[r] - d_fast[r]).abs() < 1e-4 * (1.0 + d_base[r].abs()),
                    "query {qi} rank {r}: {} vs {}",
                    d_base[r],
                    d_fast[r]
                );
            }
        }
    }

    #[test]
    fn unreranked_search_within_quantization_error() {
        let (pq, data, codes) = setup(400, 16, 4, 35);
        let packed = PackedCodes4::pack(&codes, 4).unwrap();
        let q = &data[..16];
        let luts = pq.compute_luts(q);
        let qluts = QuantizedLuts::from_f32(&luts, 4, 16);
        let (d_base, _) = search_adc(&pq, &luts, &codes, None, 1);
        let mut params = FastScanParams::default();
        params.rerank = false;
        let (d_fast, _) = search_fastscan(&pq, &packed, q, 1, &params, None);
        assert!(
            (d_base[0] - d_fast[0]).abs() <= qluts.max_abs_error() + 1e-3,
            "{} vs {} (bound {})",
            d_base[0],
            d_fast[0],
            qluts.max_abs_error()
        );
    }

    #[test]
    fn external_labels_roundtrip() {
        let (pq, data, codes) = setup(100, 16, 4, 36);
        let packed = PackedCodes4::pack(&codes, 4).unwrap();
        let ext: Vec<i64> = (0..100).map(|i| 7000 + i as i64).collect();
        let (_d, labels) = search_fastscan(
            &pq,
            &packed,
            &data[..16],
            5,
            &FastScanParams::default(),
            Some(&ext),
        );
        assert!(labels.iter().all(|&l| (7000..7100).contains(&l)));
    }

    #[test]
    fn identical_distances_to_exact_adc_decoded() {
        // fastscan + rerank distances must match exact ADC distances for
        // the same labels.
        let (pq, data, codes) = setup(200, 24, 6, 37);
        let packed = PackedCodes4::pack(&codes, 6).unwrap();
        let q = &data[5 * 24..6 * 24];
        let luts = pq.compute_luts(q);
        let all = adc_distances_all(&pq, &luts, &codes);
        let (d, l) = search_fastscan(&pq, &packed, q, 8, &FastScanParams::default(), None);
        for r in 0..8 {
            assert!((all[l[r] as usize] - d[r]).abs() < 1e-5, "rank {r}");
        }
    }

    #[test]
    fn single_vector_database() {
        let (pq, data, codes) = setup(17, 16, 4, 38); // train needs >= 16
        let one = &codes[..4];
        let packed = PackedCodes4::pack(one, 4).unwrap();
        let (d, l) = search_fastscan(&pq, &packed, &data[..16], 3, &FastScanParams::default(), None);
        assert_eq!(l[0], 0);
        assert_eq!(l[1], -1);
        assert!(d[0].is_finite());
    }
}
