//! The multi-bitwidth PQ fastscan kernel — the paper's §3, end to end,
//! generalized over code width (see [`crate::pq::bitwidth`]).
//!
//! Per 32-vector block and per 32-byte code chunk:
//!
//! 1. one 32-byte load of packed codes (virtual 256-bit register),
//! 2. nibble extraction (`& 0x0F`, `>> 4`),
//! 3. **dual-table shuffle** — the 256-bit `_mm256_shuffle_epi8` emulated
//!    as two 128-bit `vqtbl1q_u8` (Fig. 1c), wired per [`LaneWiring`]:
//!    * [`LaneWiring::PairedTables`] (2-/4-bit): lane-lo indices against
//!      `T_q`, lane-hi against `T_{q+1}`; low nibbles are vectors 0..16,
//!      high nibbles vectors 16..32,
//!    * [`LaneWiring::SplitNibble`] (8-bit): each full code byte's low
//!      nibble against `T_lo` and high nibble against `T_hi` — the paired
//!      half-space lookups of the product-structured 8-bit tables,
//! 4. zero-extend and saturating-accumulate into u16 lanes.
//!
//! After the chunk loop, 32 quantized distances are compared against the
//! current reservoir threshold with a SIMD compare + emulated `movemask`
//! (the AVX2-only instruction the paper re-creates), and only surviving
//! lanes touch the reservoir. Candidates are optionally re-ranked with the
//! exact f32 tables.
//!
//! Three differential-tested implementations per width: the portable
//! NEON-semantics model ([`crate::simd`]), a real-SIMD SSSE3 path
//! ([`crate::simd::x86`]) and a real ARM NEON path ([`crate::simd::neon`])
//! — the paper's actual target, with the dual `vqtbl1q_u8` shuffle and
//! `vshrn`-based movemask.

use crate::pq::bitwidth::build_width_luts;
use crate::pq::codebook::ProductQuantizer;
use crate::pq::layout::PackedCodes;
use crate::pq::lut::QuantizedLuts;
use crate::pq::BLOCK_SIZE;
use crate::simd::{best_backend, Backend, Simd256u16, Simd256u8};
use crate::util::topk::{TopK, U16Reservoir};

/// Register budget of the fused scans: dual-table registers are hoisted
/// out of the block loop, so the chunk count must be bounded. Larger M
/// falls back to the per-block dispatch path (same results, reloads
/// tables per block).
const MAX_CHUNKS: usize = 128;

/// How a 32-byte code chunk's nibbles address its two 16-entry table rows
/// (the kernel-level residue of [`crate::pq::bitwidth::CodeWidth`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LaneWiring {
    /// 2-/4-bit: chunk lanes are two (fused) sub-quantizers; a byte's low
    /// nibble is the code of vectors 0..16, the high nibble vectors 16..32.
    PairedTables,
    /// 8-bit: chunk lanes are the code bytes of vectors 0..16 / 16..32; a
    /// byte's low/high nibbles index the lo/hi half-space tables.
    SplitNibble,
}

/// Fastscan search options.
#[derive(Clone, Debug)]
pub struct FastScanParams {
    /// Which kernel implementation to run.
    pub backend: Backend,
    /// Re-rank reservoir candidates with exact f32 tables (default true —
    /// recovers "same accuracy" as original PQ, paper Fig. 2).
    pub rerank: bool,
    /// Reservoir over-collection factor relative to k.
    pub reservoir_factor: usize,
}

impl Default for FastScanParams {
    fn default() -> Self {
        Self { backend: best_backend(), rerank: true, reservoir_factor: 8 }
    }
}

/// LUTs padded/arranged for the kernel: `lut_rows × 16` bytes, so the row
/// pair `(2p, 2p+1)` is one contiguous 32-byte dual-table register, plus
/// the [`LaneWiring`] telling the kernel how code nibbles address the pair.
pub struct KernelLuts {
    pub bytes: Vec<u8>,
    /// 16-byte table rows (chunk count × 2; for 4-bit, M padded to even).
    pub lut_rows: usize,
    pub wiring: LaneWiring,
}

impl KernelLuts {
    /// 4-bit-compatible build: one row per quantized sub-quantizer table,
    /// paired wiring. Width-aware construction (2-bit fusing, 8-bit
    /// half-space rows) lives in [`crate::pq::bitwidth::build_width_luts`].
    pub fn build(qluts: &QuantizedLuts, lut_rows: usize) -> Self {
        Self::build_wired(qluts, lut_rows, LaneWiring::PairedTables)
    }

    /// Arrange quantized rows for the kernel with an explicit wiring.
    pub fn build_wired(qluts: &QuantizedLuts, lut_rows: usize, wiring: LaneWiring) -> Self {
        Self::build_wired_reuse(qluts, lut_rows, wiring, Vec::new())
    }

    /// [`KernelLuts::build_wired`] on recycled `bytes` storage (cleared and
    /// resized; capacity kept) — the executor's scratch path.
    pub fn build_wired_reuse(
        qluts: &QuantizedLuts,
        lut_rows: usize,
        wiring: LaneWiring,
        mut bytes: Vec<u8>,
    ) -> Self {
        assert_eq!(qluts.ksub, 16, "kernel tables are 16-entry shuffle rows");
        assert!(lut_rows >= qluts.m, "lut_rows must cover every quantized row");
        bytes.clear();
        bytes.resize(lut_rows * 16, 0);
        for mi in 0..qluts.m {
            bytes[mi * 16..(mi + 1) * 16].copy_from_slice(qluts.row(mi));
        }
        // phantom rows (odd-M padding) stay all-zero: they contribute
        // nothing to any distance.
        Self { bytes, lut_rows, wiring }
    }

    /// 32-byte chunks per block this table set expects.
    #[inline]
    pub fn chunks(&self) -> usize {
        self.lut_rows / 2
    }

    #[inline]
    pub fn pair(&self, p: usize) -> &[u8] {
        &self.bytes[p * 32..(p + 1) * 32]
    }
}

/// Block-aligned filter bitmask over scan positions: bit `v` of word `b`
/// admits position `32·b + v`. The scan kernels AND a block's word into
/// the pruned-compare admission mask, so a filtered-out position costs
/// nothing beyond the bit test — and an all-zero word skips the block's
/// accumulation entirely.
///
/// Built from a [`crate::index::query::Filter`] by the index layers
/// ([`crate::index::query::Filter::build_mask`]); the kernel itself knows
/// only positions, never external labels.
#[derive(Clone, Debug)]
pub struct FilterMask {
    words: Vec<u32>,
    n: usize,
    pass: usize,
}

impl FilterMask {
    /// Mask over `n` positions; `keep(pos)` decides admission. Bits past
    /// `n` in the last word stay zero (phantom lanes never pass).
    pub fn from_fn(n: usize, keep: impl Fn(usize) -> bool) -> Self {
        let mut words = vec![0u32; n.div_ceil(BLOCK_SIZE)];
        let mut pass = 0usize;
        for (pos, word) in (0..n).map(|p| (p, p / BLOCK_SIZE)) {
            if keep(pos) {
                words[word] |= 1u32 << (pos % BLOCK_SIZE);
                pass += 1;
            }
        }
        Self { words, n, pass }
    }

    /// Admission word of block `b` (all-ones past the mask's coverage, so
    /// a mask may be shorter than the scan it gates — unused here, but it
    /// keeps `word` total).
    #[inline]
    pub fn word(&self, b: usize) -> u32 {
        self.words.get(b).copied().unwrap_or(u32::MAX)
    }

    #[inline]
    pub fn passes(&self, pos: usize) -> bool {
        pos < self.n && self.words[pos / BLOCK_SIZE] >> (pos % BLOCK_SIZE) & 1 == 1
    }

    /// Number of positions covered.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of admitted positions.
    pub fn pass_count(&self) -> usize {
        self.pass
    }

    /// Admitted fraction (1.0 for an empty domain).
    pub fn selectivity(&self) -> f64 {
        if self.n == 0 {
            1.0
        } else {
            self.pass as f64 / self.n as f64
        }
    }
}

/// Scan-side cost counters for one packed code region, as the tracing
/// layer attributes them (codes considered, blocks and bytes walked, and
/// how many of those bytes were windows into a mapped file). Derived
/// from the region's frozen layout — the kernels themselves stay
/// untouched, so counting costs nothing on the scan path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScanCounts {
    /// Code positions the region holds (every one is a candidate the
    /// admission mask decides on).
    pub codes: usize,
    /// 32-vector blocks the scan walks.
    pub blocks: usize,
    /// Packed code bytes behind those blocks.
    pub code_bytes: usize,
    /// Of `code_bytes`, how many live in a mapped (zero-copy) region.
    pub mapped_bytes: usize,
}

impl ScanCounts {
    /// The counters a full scan of `packed` incurs.
    pub fn of(packed: &PackedCodes) -> ScanCounts {
        ScanCounts {
            codes: packed.n,
            blocks: packed.nblocks(),
            code_bytes: packed.nblocks() * packed.block_bytes(),
            mapped_bytes: packed.mapped_bytes(),
        }
    }
}

/// [`scan_filtered`] plus the region's [`ScanCounts`] — the entry the
/// traced query paths use so span counters and kernel admission can never
/// disagree about what was scanned.
pub fn scan_filtered_counted(
    packed: &PackedCodes,
    luts: &KernelLuts,
    backend: Backend,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
    sink: &mut ScanSink<'_>,
) -> ScanCounts {
    scan_filtered(packed, luts, backend, labels, filter, sink);
    ScanCounts::of(packed)
}

/// Where scanned candidates go: the top-k reservoir (threshold tightens as
/// it fills) or a range collector (fixed quantized threshold, unbounded
/// hits). One enum instead of a trait so the fused `#[target_feature]`
/// kernels stay free of dynamic dispatch.
pub enum ScanSink<'a> {
    TopK(&'a mut U16Reservoir),
    Range {
        /// Admit quantized distances `<= bound`.
        bound: u16,
        hits: &'a mut Vec<(u16, i64)>,
    },
}

impl ScanSink<'_> {
    /// `(prune, threshold)` for the SIMD admission test: when `prune` is
    /// false every real lane is admitted (underfull reservoir, or a range
    /// bound of `u16::MAX` that a strict `<` compare could not express);
    /// otherwise lanes pass iff `d < threshold`.
    #[inline]
    fn admission(&self) -> (bool, u16) {
        match self {
            ScanSink::TopK(res) => (res.is_full(), res.threshold()),
            // d <= bound  ⟺  d < bound + 1 (strict SIMD compare)
            ScanSink::Range { bound, .. } => {
                if *bound == u16::MAX {
                    (false, 0)
                } else {
                    (true, bound + 1)
                }
            }
        }
    }

    #[inline]
    fn push(&mut self, d: u16, label: i64) {
        match self {
            ScanSink::TopK(res) => res.push(d, label),
            ScanSink::Range { bound, hits } => {
                if d <= *bound {
                    hits.push((d, label));
                }
            }
        }
    }
}

// ------------------------------------------------------------------ kernels

/// Portable (NEON-semantics) block kernel: 32 quantized distances.
#[inline]
pub fn accumulate_block_portable(block: &[u8], luts: &KernelLuts, out: &mut [u16; BLOCK_SIZE]) {
    let nchunks = luts.chunks();
    let split = luts.wiring == LaneWiring::SplitNibble;
    let mask = Simd256u8::splat(0x0F);
    let mut acc_a = Simd256u16::zero(); // vectors 0..16
    let mut acc_b = Simd256u16::zero(); // vectors 16..32
    for p in 0..nchunks {
        let c = Simd256u8::load(&block[p * 32..(p + 1) * 32]);
        // index registers feeding the two shuffles; in both wirings r0's
        // lanes all belong to vectors 0..16 and r1's to vectors 16..32
        let (i0, i1) = if split {
            // 8-bit: lane-lo = low nibbles → T_lo, lane-hi = high → T_hi
            (c.nibble_split_lo(), c.nibble_split_hi())
        } else {
            // 2-/4-bit: low nibbles = (fused) codes of (q, q+1) for v0..15,
            // high nibbles the same for v16..31 (already < 16 after shr4)
            (c.and(mask), c.shr4())
        };
        let tables = Simd256u8::load(luts.pair(p)); // lane-lo: T_q/T_lo, lane-hi: T_{q+1}/T_hi
        let r0 = Simd256u8::shuffle_dual(tables, i0);
        let r1 = Simd256u8::shuffle_dual(tables, i1);
        let (w00, w01) = r0.widen(); // both table contributions for v0..15
        acc_a = acc_a.sat_add(w00).sat_add(w01);
        let (w10, w11) = r1.widen();
        acc_b = acc_b.sat_add(w10).sat_add(w11);
    }
    acc_a.store(&mut out[..16]);
    acc_b.store(&mut out[16..]);
}

/// Real-SIMD SSSE3 block kernel (x86_64). Same structure, `pshufb` lanes.
///
/// # Safety
/// Caller must ensure SSSE3 is available ([`best_backend`]).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
pub unsafe fn accumulate_block_ssse3(block: &[u8], luts: &KernelLuts, out: &mut [u16; BLOCK_SIZE]) {
    use crate::simd::x86::{X86Simd256u16, X86Simd256u8};
    let nchunks = luts.chunks();
    let split = luts.wiring == LaneWiring::SplitNibble;
    let mask = X86Simd256u8::splat(0x0F);
    let mut acc_a = X86Simd256u16::zero();
    let mut acc_b = X86Simd256u16::zero();
    for p in 0..nchunks {
        let c = X86Simd256u8::load(block.as_ptr().add(p * 32));
        let clo = c.and(mask);
        let chi = c.shr4(); // includes the &0x0F internally
        // paired: lo/hi nibbles are the vector halves; split (8-bit): each
        // lane's lo/hi nibbles address T_lo/T_hi for that lane's vectors
        let (i0, i1) = if split {
            (
                X86Simd256u8 { lo: clo.lo, hi: chi.lo },
                X86Simd256u8 { lo: clo.hi, hi: chi.hi },
            )
        } else {
            (clo, chi)
        };
        let tables = X86Simd256u8::load(luts.bytes.as_ptr().add(p * 32));
        let r0 = X86Simd256u8::shuffle_dual(tables, i0);
        let r1 = X86Simd256u8::shuffle_dual(tables, i1);
        let (w00, w01) = r0.widen();
        acc_a = acc_a.sat_add(w00).sat_add(w01);
        let (w10, w11) = r1.widen();
        acc_b = acc_b.sat_add(w10).sat_add(w11);
    }
    acc_a.store(out.as_mut_ptr());
    acc_b.store(out.as_mut_ptr().add(16));
}

/// Real ARM NEON block kernel (aarch64) — the paper's §3 on its target
/// ISA: one 32-byte load per pair, nibble extraction, the dual
/// `vqtbl1q_u8` shuffle, `vmovl_u8`/`vmovl_high_u8` widening and
/// saturating u16 accumulation.
///
/// # Safety
/// Caller must ensure NEON is available ([`best_backend`]) — it always is
/// on aarch64.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
pub unsafe fn accumulate_block_neon(block: &[u8], luts: &KernelLuts, out: &mut [u16; BLOCK_SIZE]) {
    use crate::simd::neon::{NeonSimd256u16, NeonSimd256u8};
    let nchunks = luts.chunks();
    let split = luts.wiring == LaneWiring::SplitNibble;
    let mask = NeonSimd256u8::splat(0x0F);
    let mut acc_a = NeonSimd256u16::zero(); // vectors 0..16
    let mut acc_b = NeonSimd256u16::zero(); // vectors 16..32
    for p in 0..nchunks {
        let c = NeonSimd256u8::load(block.as_ptr().add(p * 32));
        let clo = c.and(mask);
        let chi = c.shr4(); // already < 16
        // paired: lo/hi nibbles are the vector halves; split (8-bit): each
        // lane's lo/hi nibbles address T_lo/T_hi for that lane's vectors
        let (i0, i1) = if split {
            (
                NeonSimd256u8 { lo: clo.lo, hi: chi.lo },
                NeonSimd256u8 { lo: clo.hi, hi: chi.hi },
            )
        } else {
            (clo, chi)
        };
        let tables = NeonSimd256u8::load(luts.bytes.as_ptr().add(p * 32));
        let r0 = NeonSimd256u8::shuffle_dual(tables, i0);
        let r1 = NeonSimd256u8::shuffle_dual(tables, i1);
        let (w00, w01) = r0.widen();
        acc_a = acc_a.sat_add(w00).sat_add(w01);
        let (w10, w11) = r1.widen();
        acc_b = acc_b.sat_add(w10).sat_add(w11);
    }
    acc_a.store(out.as_mut_ptr());
    acc_b.store(out.as_mut_ptr().add(16));
}

/// Dispatch one block through the chosen backend. A real-SIMD backend
/// requested on the wrong architecture degrades to the portable model
/// (same results; the arms below are what keep cross-arch code paths
/// compiling).
#[inline]
fn accumulate_block(
    backend: Backend,
    block: &[u8],
    luts: &KernelLuts,
    out: &mut [u16; BLOCK_SIZE],
) {
    match backend {
        Backend::Portable => accumulate_block_portable(block, luts, out),
        #[cfg(target_arch = "x86_64")]
        Backend::Ssse3 => unsafe { accumulate_block_ssse3(block, luts, out) },
        #[cfg(target_arch = "aarch64")]
        Backend::Neon => unsafe { accumulate_block_neon(block, luts, out) },
        _ => accumulate_block_portable(block, luts, out),
    }
}

/// All quantized distances (n entries) — tests, ablations, IVF internals.
pub fn fastscan_distances_all(
    packed: &PackedCodes,
    luts: &KernelLuts,
    backend: Backend,
) -> Vec<u16> {
    debug_assert_eq!(
        luts.chunks(),
        packed.chunks(),
        "LUT chunk count must match the packed layout (same m and width)"
    );
    let mut out = vec![0u16; packed.n];
    let mut block_d = [0u16; BLOCK_SIZE];
    let bb = packed.block_bytes();
    for b in 0..packed.nblocks() {
        accumulate_block(backend, &packed.data[b * bb..(b + 1) * bb], luts, &mut block_d);
        let base = b * BLOCK_SIZE;
        let take = BLOCK_SIZE.min(packed.n - base);
        out[base..base + take].copy_from_slice(&block_d[..take]);
    }
    out
}

/// Scan all blocks into a reservoir, SIMD-pruning lanes above the current
/// threshold via compare + emulated movemask.
///
/// While the reservoir is below capacity *every* lane is admitted — a
/// strict `d < threshold` test alone would starve distances saturated at
/// `u16::MAX`, returning fewer than `k` results on far-away databases.
pub fn scan_into_reservoir(
    packed: &PackedCodes,
    luts: &KernelLuts,
    backend: Backend,
    labels: Option<&[i64]>,
    reservoir: &mut U16Reservoir,
) {
    let mut sink = ScanSink::TopK(reservoir);
    scan_filtered(packed, luts, backend, labels, None, &mut sink);
}

/// The filtered, sink-generic scan every query mode runs on: dispatches to
/// the fused SSSE3/NEON hot paths or the per-block fallback, AND-ing the
/// block-aligned [`FilterMask`] into the admission mask so filtered-out
/// positions never touch the sink (and all-filtered blocks skip
/// accumulation entirely). All three backends stay bit-identical — the
/// filter word is applied to the scalar admission mask the same way on
/// every path.
pub fn scan_filtered(
    packed: &PackedCodes,
    luts: &KernelLuts,
    backend: Backend,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
    sink: &mut ScanSink<'_>,
) {
    // A LUT set built for a different (m, width) than the packed codes
    // would make the fused unsafe scans read past the block.
    debug_assert_eq!(
        luts.chunks(),
        packed.chunks(),
        "LUT chunk count must match the packed layout (same m and width)"
    );
    if let Some(f) = filter {
        debug_assert_eq!(f.n(), packed.n, "filter mask must cover every scan position");
    }
    // Fused hot paths: tables hoisted into registers across all blocks,
    // in-register threshold compare, stores only for surviving blocks.
    // They hold the whole dual-table set in registers, so they are gated
    // on the chunk-count budget; larger M uses the per-block path below.
    let nchunks = luts.chunks();
    #[cfg(target_arch = "x86_64")]
    if backend == Backend::Ssse3 && nchunks <= MAX_CHUNKS {
        unsafe { scan_fused_ssse3(packed, luts, labels, filter, sink) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if backend == Backend::Neon && nchunks <= MAX_CHUNKS {
        unsafe { scan_fused_neon(packed, luts, labels, filter, sink) };
        return;
    }
    let _ = nchunks;
    scan_blocks(packed, luts, backend, labels, filter, sink);
}

/// Generic scan: per-block kernel dispatch plus the portable SIMD
/// threshold test. Used by the portable backend and as the fallback for
/// real-SIMD backends when M exceeds the fused-kernel register budget.
fn scan_blocks(
    packed: &PackedCodes,
    luts: &KernelLuts,
    backend: Backend,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
    sink: &mut ScanSink<'_>,
) {
    let mut block_d = [0u16; BLOCK_SIZE];
    let bb = packed.block_bytes();
    let nblocks = packed.nblocks();
    for b in 0..nblocks {
        let fw = filter.map(|f| f.word(b)).unwrap_or(u32::MAX);
        if fw == 0 {
            continue; // every position filtered out: skip the block
        }
        accumulate_block(backend, &packed.data[b * bb..(b + 1) * bb], luts, &mut block_d);
        let base = b * BLOCK_SIZE;
        let limit = BLOCK_SIZE.min(packed.n - base);
        let (prune, thr) = sink.admission();
        if prune && thr == 0 {
            continue; // nothing can beat a zero threshold
        }

        let mut mask = if prune {
            // SIMD threshold test: two Simd256u16 lane groups → 32-bit mask.
            let thr_v = Simd256u16::splat(thr);
            let lo = Simd256u16 {
                lo: crate::simd::U16x8(block_d[0..8].try_into().unwrap()),
                hi: crate::simd::U16x8(block_d[8..16].try_into().unwrap()),
            };
            let hi = Simd256u16 {
                lo: crate::simd::U16x8(block_d[16..24].try_into().unwrap()),
                hi: crate::simd::U16x8(block_d[24..32].try_into().unwrap()),
            };
            (lo.lt(thr_v).movemask() as u32) | ((hi.lt(thr_v).movemask() as u32) << 16)
        } else {
            u32::MAX // underfull reservoir / saturated range bound: admit every real lane
        };
        mask &= fw; // filter pushdown: drop filtered positions in the admission mask
        if limit < BLOCK_SIZE {
            mask &= (1u32 << limit) - 1; // drop phantom padding lanes
        }
        while mask != 0 {
            let v = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let idx = base + v;
            let label = labels.map(|l| l[idx]).unwrap_or(idx as i64);
            sink.push(block_d[v], label);
        }
    }
}

/// Fused SSSE3 scan (the §Perf hot path):
///
/// * the `lut_rows/2` dual-table registers are loaded **once** and stay in
///   registers across all blocks (the paper's register-resident tables,
///   taken to its limit),
/// * the reservoir threshold test happens **in-register** on the u16
///   accumulators (`subs_epu16` + `cmpeq` + `packs` + `movemask` — the
///   unsigned-compare idiom, since SSE2 lacks `cmplt_epu16`),
/// * distances are stored to memory only when some lane survives, which is
///   rare once the threshold tightens.
///
/// # Safety
/// Caller must ensure SSSE3 is available.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "ssse3")]
unsafe fn scan_fused_ssse3(
    packed: &PackedCodes,
    luts: &KernelLuts,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
    sink: &mut ScanSink<'_>,
) {
    #![allow(unsafe_op_in_unsafe_fn)]
    use core::arch::x86_64::*;
    let nchunks = luts.chunks();
    let split = luts.wiring == LaneWiring::SplitNibble;
    debug_assert!(nchunks <= MAX_CHUNKS, "caller gates on MAX_CHUNKS");

    // hoist the dual-table registers out of the block loop
    let mut tables = [unsafe { _mm_setzero_si128() }; MAX_CHUNKS * 2];
    for p in 0..nchunks {
        let ptr = luts.bytes.as_ptr().add(p * 32);
        tables[2 * p] = _mm_loadu_si128(ptr as *const __m128i);
        tables[2 * p + 1] = _mm_loadu_si128(ptr.add(16) as *const __m128i);
    }
    let nib = _mm_set1_epi8(0x0F);
    let zero = _mm_setzero_si128();

    let bb = packed.block_bytes();
    let nblocks = packed.nblocks();
    let data = packed.data.as_ptr();
    let mut block_d = [0u16; BLOCK_SIZE];

    for b in 0..nblocks {
        let fw = match filter {
            Some(f) => f.word(b),
            None => u32::MAX,
        };
        if fw == 0 {
            continue; // every position filtered out: skip accumulation too
        }
        let base_ptr = data.add(b * bb);
        // accumulators: 4 × 8 u16 lanes covering vectors 0..32
        let mut a0 = zero; // v0..8
        let mut a1 = zero; // v8..16
        let mut a2 = zero; // v16..24
        let mut a3 = zero; // v24..32
        for p in 0..nchunks {
            let c_lo = _mm_loadu_si128(base_ptr.add(p * 32) as *const __m128i);
            let c_hi = _mm_loadu_si128(base_ptr.add(p * 32 + 16) as *const __m128i);
            let t_lo = tables[2 * p];
            let t_hi = tables[2 * p + 1];
            let n_lo = _mm_and_si128(c_lo, nib); // low nibbles, bytes 0..16
            let n_hi = _mm_and_si128(c_hi, nib); // low nibbles, bytes 16..32
            let s_lo = _mm_and_si128(_mm_srli_epi16(c_lo, 4), nib); // high nibbles
            let s_hi = _mm_and_si128(_mm_srli_epi16(c_hi, 4), nib);
            // wiring: which nibble register feeds which table for which
            // vector half. paired (2-/4-bit): nibbles are vector halves;
            // split (8-bit): nibbles are the lo/hi half-space indices of
            // the byte's own vector half.
            let (ia0, ia1, ib0, ib1) =
                if split { (n_lo, s_lo, n_hi, s_hi) } else { (n_lo, n_hi, s_lo, s_hi) };
            // v0..16 contributions (both table rows feed the same vectors
            // — the faiss "fixup" merged into the add chain)
            let r0_lo = _mm_shuffle_epi8(t_lo, ia0);
            let r0_hi = _mm_shuffle_epi8(t_hi, ia1);
            // v16..32 contributions
            let r1_lo = _mm_shuffle_epi8(t_lo, ib0);
            let r1_hi = _mm_shuffle_epi8(t_hi, ib1);
            a0 = _mm_adds_epu16(a0, _mm_unpacklo_epi8(r0_lo, zero));
            a1 = _mm_adds_epu16(a1, _mm_unpackhi_epi8(r0_lo, zero));
            a0 = _mm_adds_epu16(a0, _mm_unpacklo_epi8(r0_hi, zero));
            a1 = _mm_adds_epu16(a1, _mm_unpackhi_epi8(r0_hi, zero));
            a2 = _mm_adds_epu16(a2, _mm_unpacklo_epi8(r1_lo, zero));
            a3 = _mm_adds_epu16(a3, _mm_unpackhi_epi8(r1_lo, zero));
            a2 = _mm_adds_epu16(a2, _mm_unpacklo_epi8(r1_hi, zero));
            a3 = _mm_adds_epu16(a3, _mm_unpackhi_epi8(r1_hi, zero));
        }
        // in-register threshold: acc < thr ⟺ subs_epu16(acc, thr-1) == 0.
        // An underfull reservoir admits everything (saturated distances
        // included), so pruning only starts once it reaches capacity.
        let (prune, thr) = sink.admission();
        if prune && thr == 0 {
            continue;
        }
        let mut mask = if prune {
            let thr_m1 = _mm_set1_epi16(thr.wrapping_sub(1) as i16);
            let c0 = _mm_cmpeq_epi16(_mm_subs_epu16(a0, thr_m1), zero);
            let c1 = _mm_cmpeq_epi16(_mm_subs_epu16(a1, thr_m1), zero);
            let c2 = _mm_cmpeq_epi16(_mm_subs_epu16(a2, thr_m1), zero);
            let c3 = _mm_cmpeq_epi16(_mm_subs_epu16(a3, thr_m1), zero);
            let mask_lo = _mm_movemask_epi8(_mm_packs_epi16(c0, c1)) as u32;
            let mask_hi = _mm_movemask_epi8(_mm_packs_epi16(c2, c3)) as u32;
            mask_lo | (mask_hi << 16)
        } else {
            u32::MAX
        };
        mask &= fw; // filter pushdown into the admission mask
        if mask == 0 {
            continue; // common case once the threshold tightens: no stores
        }
        let base = b * BLOCK_SIZE;
        let limit = BLOCK_SIZE.min(packed.n - base);
        if limit < BLOCK_SIZE {
            mask &= (1u32 << limit) - 1;
        }
        _mm_storeu_si128(block_d.as_mut_ptr() as *mut __m128i, a0);
        _mm_storeu_si128(block_d.as_mut_ptr().add(8) as *mut __m128i, a1);
        _mm_storeu_si128(block_d.as_mut_ptr().add(16) as *mut __m128i, a2);
        _mm_storeu_si128(block_d.as_mut_ptr().add(24) as *mut __m128i, a3);
        while mask != 0 {
            let v = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let idx = base + v;
            let label = labels.map(|l| l[idx]).unwrap_or(idx as i64);
            sink.push(block_d[v], label);
        }
    }
}

/// Fused NEON scan — the paper's hot path on its target ISA:
///
/// * the `lut_rows/2` dual-table registers (`uint8x16x2_t` pairs) are loaded
///   **once** and stay in Q-registers across all blocks (the paper's
///   register-resident tables, taken to its limit),
/// * the reservoir threshold test happens **in-register** on the u16
///   accumulators with the native unsigned compare `vcltq_u16`, narrowed
///   to a byte mask with `vshrn_n_u16` and collapsed to a scalar bitmask
///   via the `vshrn` + scalar-extract movemask idiom,
/// * distances are stored to memory only when some lane survives, which is
///   rare once the threshold tightens.
///
/// # Safety
/// Caller must ensure NEON is available (always true on aarch64).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn scan_fused_neon(
    packed: &PackedCodes,
    luts: &KernelLuts,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
    sink: &mut ScanSink<'_>,
) {
    #![allow(unsafe_op_in_unsafe_fn)]
    use crate::simd::neon::neon_movemask_u8;
    use core::arch::aarch64::*;
    let nchunks = luts.chunks();
    let split = luts.wiring == LaneWiring::SplitNibble;
    debug_assert!(nchunks <= MAX_CHUNKS, "caller gates on MAX_CHUNKS");

    // hoist the dual-table registers out of the block loop
    let mut tables = [vdupq_n_u8(0); MAX_CHUNKS * 2];
    for p in 0..nchunks {
        let ptr = luts.bytes.as_ptr().add(p * 32);
        tables[2 * p] = vld1q_u8(ptr);
        tables[2 * p + 1] = vld1q_u8(ptr.add(16));
    }
    let nib = vdupq_n_u8(0x0F);
    let zero16 = vdupq_n_u16(0);

    let bb = packed.block_bytes();
    let nblocks = packed.nblocks();
    let data = packed.data.as_ptr();
    let mut block_d = [0u16; BLOCK_SIZE];

    for b in 0..nblocks {
        let fw = match filter {
            Some(f) => f.word(b),
            None => u32::MAX,
        };
        if fw == 0 {
            continue; // every position filtered out: skip accumulation too
        }
        let base_ptr = data.add(b * bb);
        // accumulators: 4 × 8 u16 lanes covering vectors 0..32
        let mut a0 = zero16; // v0..8
        let mut a1 = zero16; // v8..16
        let mut a2 = zero16; // v16..24
        let mut a3 = zero16; // v24..32
        for p in 0..nchunks {
            let c_lo = vld1q_u8(base_ptr.add(p * 32)); // chunk bytes 0..16
            let c_hi = vld1q_u8(base_ptr.add(p * 32 + 16)); // chunk bytes 16..32
            let t_lo = tables[2 * p];
            let t_hi = tables[2 * p + 1];
            let n_lo = vandq_u8(c_lo, nib); // low nibbles, bytes 0..16
            let n_hi = vandq_u8(c_hi, nib); // low nibbles, bytes 16..32
            let s_lo = vshrq_n_u8::<4>(c_lo); // high nibbles (already < 16)
            let s_hi = vshrq_n_u8::<4>(c_hi);
            // wiring: paired (2-/4-bit) nibbles are the vector halves;
            // split (8-bit) nibbles are the lo/hi half-space indices of
            // the byte's own vector half.
            let (ia0, ia1, ib0, ib1) =
                if split { (n_lo, s_lo, n_hi, s_hi) } else { (n_lo, n_hi, s_lo, s_hi) };
            // v0..16 contributions (both table rows feed the same vectors)
            let r0_lo = vqtbl1q_u8(t_lo, ia0);
            let r0_hi = vqtbl1q_u8(t_hi, ia1);
            // v16..32 contributions
            let r1_lo = vqtbl1q_u8(t_lo, ib0);
            let r1_hi = vqtbl1q_u8(t_hi, ib1);
            // widen + saturating accumulate (the faiss "fixup" merged into
            // the add chain)
            a0 = vqaddq_u16(a0, vmovl_u8(vget_low_u8(r0_lo)));
            a1 = vqaddq_u16(a1, vmovl_high_u8(r0_lo));
            a0 = vqaddq_u16(a0, vmovl_u8(vget_low_u8(r0_hi)));
            a1 = vqaddq_u16(a1, vmovl_high_u8(r0_hi));
            a2 = vqaddq_u16(a2, vmovl_u8(vget_low_u8(r1_lo)));
            a3 = vqaddq_u16(a3, vmovl_high_u8(r1_lo));
            a2 = vqaddq_u16(a2, vmovl_u8(vget_low_u8(r1_hi)));
            a3 = vqaddq_u16(a3, vmovl_high_u8(r1_hi));
        }
        // in-register threshold: native unsigned compare, then the
        // narrowing-shift movemask. Underfull reservoir admits everything.
        let (prune, thr) = sink.admission();
        if prune && thr == 0 {
            continue;
        }
        let mut mask = if prune {
            let thr_v = vdupq_n_u16(thr);
            let c0 = vcltq_u16(a0, thr_v);
            let c1 = vcltq_u16(a1, thr_v);
            let c2 = vcltq_u16(a2, thr_v);
            let c3 = vcltq_u16(a3, thr_v);
            // narrow each 0xFFFF/0x0000 u16 lane to a 0xFF/0x00 byte
            let m01 = vcombine_u8(vshrn_n_u16::<8>(c0), vshrn_n_u16::<8>(c1));
            let m23 = vcombine_u8(vshrn_n_u16::<8>(c2), vshrn_n_u16::<8>(c3));
            (neon_movemask_u8(m01) as u32) | ((neon_movemask_u8(m23) as u32) << 16)
        } else {
            u32::MAX
        };
        mask &= fw; // filter pushdown into the admission mask
        if mask == 0 {
            continue; // common case once the threshold tightens: no stores
        }
        let base = b * BLOCK_SIZE;
        let limit = BLOCK_SIZE.min(packed.n - base);
        if limit < BLOCK_SIZE {
            mask &= (1u32 << limit) - 1;
        }
        vst1q_u16(block_d.as_mut_ptr(), a0);
        vst1q_u16(block_d.as_mut_ptr().add(8), a1);
        vst1q_u16(block_d.as_mut_ptr().add(16), a2);
        vst1q_u16(block_d.as_mut_ptr().add(24), a3);
        while mask != 0 {
            let v = mask.trailing_zeros() as usize;
            mask &= mask - 1;
            let idx = base + v;
            let label = labels.map(|l| l[idx]).unwrap_or(idx as i64);
            sink.push(block_d[v], label);
        }
    }
}

/// Full width-generic PQ fastscan search: build LUTs from `query`,
/// quantize/fuse per the packed width, scan, re-rank.
///
/// `pq` is the *internal* quantizer (`packed.m_codes` columns of
/// `width.sub_ksub()` codewords — what `CodeWidth::pq_params` trained).
/// `labels` maps scan position → external id (identity if `None`).
pub fn search_fastscan(
    pq: &ProductQuantizer,
    packed: &PackedCodes,
    query: &[f32],
    k: usize,
    params: &FastScanParams,
    labels: Option<&[i64]>,
) -> (Vec<f32>, Vec<i64>) {
    let luts_f32 = pq.compute_luts(query);
    search_fastscan_with_luts(pq, packed, &luts_f32, k, params, labels)
}

/// Same as [`search_fastscan`] but with precomputed f32 LUTs
/// (`m_codes × sub_ksub`) — the IVF path reuses one LUT set across probed
/// lists, and the coordinator reuses it across shard fan-out.
pub fn search_fastscan_with_luts(
    pq: &ProductQuantizer,
    packed: &PackedCodes,
    luts_f32: &[f32],
    k: usize,
    params: &FastScanParams,
    labels: Option<&[i64]>,
) -> (Vec<f32>, Vec<i64>) {
    let hits = topk_fastscan_with_luts(pq, packed, luts_f32, k, params, labels, None);
    let mut d: Vec<f32> = hits.iter().map(|&(dist, _)| dist).collect();
    let mut l: Vec<i64> = hits.iter().map(|&(_, label)| label).collect();
    while d.len() < k {
        d.push(f32::INFINITY);
        l.push(-1);
    }
    (d, l)
}

fn check_scan_shapes(pq: &ProductQuantizer, packed: &PackedCodes, labels: Option<&[i64]>) {
    if let Some(ls) = labels {
        // A wrong-sized label map would silently mislabel (or panic on)
        // results; fail loudly with the actual sizes instead.
        assert_eq!(
            ls.len(),
            packed.n,
            "labels length {} does not match packed vector count {}",
            ls.len(),
            packed.n
        );
    }
    assert_eq!(
        pq.m, packed.m_codes,
        "quantizer columns {} do not match packed layout columns {} ({})",
        pq.m, packed.m_codes, packed.width
    );
}

/// Filtered top-k over one packed code set: the `k` best `(distance,
/// label)` pairs among positions the `filter` mask admits, ascending,
/// unpadded (fewer than `k` when the admitted set is small). `filter` is
/// in *position* space (see [`FilterMask`]); `labels` renames results only.
pub fn topk_fastscan_with_luts(
    pq: &ProductQuantizer,
    packed: &PackedCodes,
    luts_f32: &[f32],
    k: usize,
    params: &FastScanParams,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
) -> Vec<(f32, i64)> {
    check_scan_shapes(pq, packed, labels);
    if k == 0 {
        return Vec::new();
    }
    let wl = build_width_luts(luts_f32, packed.m, packed.width);
    let (qluts, kluts) = (wl.qluts, wl.kernel);
    let mut reservoir = U16Reservoir::new(k, params.reservoir_factor);
    // Scan with identity labels so the reservoir carries *scan positions*;
    // external labels are applied after re-ranking. (A label→position
    // reverse map would collapse duplicate labels and panic on unmapped
    // ones — positions are unambiguous by construction.)
    {
        let mut sink = ScanSink::TopK(&mut reservoir);
        scan_filtered(packed, &kluts, params.backend, None, filter, &mut sink);
    }
    let cands = reservoir.into_candidates();

    let label_of = |pos: i64| labels.map(|l| l[pos as usize]).unwrap_or(pos);
    let mut heap = TopK::new(k);
    if params.rerank {
        // exact ADC on the survivors, addressed by scan position
        let mut codes_buf = vec![0u8; pq.m];
        for (_, pos) in cands {
            let i = pos as usize;
            for q in 0..pq.m {
                codes_buf[q] = packed.code_at(i, q);
            }
            heap.push(pq.adc_distance(luts_f32, &codes_buf), label_of(pos));
        }
    } else {
        for (d16, pos) in cands {
            heap.push(qluts.decode(d16), label_of(pos));
        }
    }
    heap.into_hits()
}

/// Range query over one packed code set: every `(distance, label)` with
/// distance `<= radius`, ascending by `(distance, label)`.
///
/// The scan reuses the u16-quantized LUT threshold: candidates are
/// collected in-register against a conservative quantized bound (the
/// radius widened by the tables' worst-case decode error when re-ranking),
/// then the exact pass trims to the true radius. With `rerank` off the
/// boundary is decided on decoded quantized distances — quantization-
/// accurate, still deterministic and backend-identical.
pub fn range_fastscan_with_luts(
    pq: &ProductQuantizer,
    packed: &PackedCodes,
    luts_f32: &[f32],
    radius: f32,
    params: &FastScanParams,
    labels: Option<&[i64]>,
    filter: Option<&FilterMask>,
) -> Vec<(f32, i64)> {
    check_scan_shapes(pq, packed, labels);
    let wl = build_width_luts(luts_f32, packed.m, packed.width);
    let (qluts, kluts) = (wl.qluts, wl.kernel);
    let bound = qluts.collection_bound(radius, params.rerank);
    let mut raw: Vec<(u16, i64)> = Vec::new();
    {
        let mut sink = ScanSink::Range { bound, hits: &mut raw };
        scan_filtered(packed, &kluts, params.backend, None, filter, &mut sink);
    }
    let label_of = |pos: i64| labels.map(|l| l[pos as usize]).unwrap_or(pos);
    let mut hits: Vec<(f32, i64)> = if params.rerank {
        let mut codes_buf = vec![0u8; pq.m];
        let mut out = Vec::with_capacity(raw.len());
        for (_, pos) in raw {
            let i = pos as usize;
            for q in 0..pq.m {
                codes_buf[q] = packed.code_at(i, q);
            }
            let d = pq.adc_distance(luts_f32, &codes_buf);
            if d <= radius {
                out.push((d, label_of(pos)));
            }
        }
        out
    } else {
        raw.into_iter().map(|(d16, pos)| (qluts.decode(d16), label_of(pos))).collect()
    };
    hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    hits
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pq::adc::{adc_distances_all, search_adc};
    use crate::pq::bitwidth::CodeWidth;
    use crate::pq::codebook::PqParams;
    use crate::simd::available_backends;
    use crate::util::rng::Rng;

    /// Random internal codes + f32 tables for a width, plus the scalar
    /// reference distance of each vector computed straight from the
    /// quantized width rows (fused rows for 2-bit).
    fn width_fixture(
        n: usize,
        m: usize,
        width: CodeWidth,
        seed: u64,
    ) -> (PackedCodes, crate::pq::bitwidth::WidthLuts, Vec<u16>) {
        let mut rng = Rng::new(seed);
        let cols = width.code_columns(m);
        let sub_ksub = width.sub_ksub();
        let codes: Vec<u8> =
            (0..n * cols).map(|_| (rng.next_u32() as usize % sub_ksub) as u8).collect();
        let luts_f32: Vec<f32> =
            (0..cols * sub_ksub).map(|_| rng.next_f32() * 9.0).collect();
        let packed = PackedCodes::pack(&codes, m, width).unwrap();
        let wl = build_width_luts(&luts_f32, m, width);
        let expect: Vec<u16> = (0..n)
            .map(|i| {
                let row = &codes[i * cols..(i + 1) * cols];
                let mut acc: u16 = 0;
                match width {
                    CodeWidth::W2 => {
                        for p in 0..m.div_ceil(2) {
                            let c1 = if 2 * p + 1 < m { row[2 * p + 1] } else { 0 };
                            let idx = (row[2 * p] | (c1 << 2)) as usize;
                            acc = acc.saturating_add(wl.qluts.row(p)[idx] as u16);
                        }
                    }
                    _ => {
                        for (col, &c) in row.iter().enumerate() {
                            acc = acc.saturating_add(wl.qluts.row(col)[c as usize] as u16);
                        }
                    }
                }
                acc
            })
            .collect();
        (packed, wl, expect)
    }

    /// The central multi-width correctness property: for every width and
    /// every backend, the SIMD kernel's quantized distances equal the
    /// scalar sum over the width's table rows — including odd M and
    /// partial blocks.
    #[test]
    fn kernel_matches_scalar_sum_all_widths() {
        for width in CodeWidth::ALL {
            for &(n, m) in &[(32usize, 2usize), (100, 8), (33, 16), (64, 5), (7, 3), (41, 1)] {
                let (packed, wl, expect) =
                    width_fixture(n, m, width, 300 + n as u64 * 7 + m as u64);
                for backend in available_backends() {
                    let got = fastscan_distances_all(&packed, &wl.kernel, backend);
                    assert_eq!(got, expect, "{width} n={n} m={m} {backend:?}");
                }
            }
        }
    }

    /// Acceptance criterion: for each width, all backends this host offers
    /// produce *bit-identical reservoir contents* on random data (the
    /// portable model is the semantic reference; CI runs portable-vs-SSSE3
    /// on x86_64 and portable-vs-NEON under QEMU).
    #[test]
    fn reservoir_contents_bit_identical_across_backends_per_width() {
        let backends = available_backends();
        let mut rng = Rng::new(41);
        for width in CodeWidth::ALL {
            for trial in 0..8 {
                let n = 1 + rng.below(300);
                let m = 1 + rng.below(12);
                let k = 1 + rng.below(8);
                let (packed, wl, _) =
                    width_fixture(n, m, width, 500 + trial * 17 + m as u64);
                let mut reference: Option<Vec<(u16, i64)>> = None;
                for &backend in &backends {
                    let mut res = U16Reservoir::new(k, 4);
                    scan_into_reservoir(&packed, &wl.kernel, backend, None, &mut res);
                    let mut cands = res.into_candidates();
                    cands.sort_unstable();
                    match &reference {
                        None => reference = Some(cands),
                        Some(want) => assert_eq!(
                            &cands, want,
                            "{width} trial {trial} n={n} m={m} k={k} {backend:?}"
                        ),
                    }
                }
            }
        }
    }

    /// End-to-end per-width search on real trained quantizers: re-ranked
    /// fastscan must agree with the exact ADC scan over the same internal
    /// codes, for every width and backend.
    #[test]
    fn reranked_search_matches_adc_all_widths() {
        let mut rng = Rng::new(42);
        let dim = 32;
        let n = 400;
        let m = 8;
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian()).collect();
        for width in CodeWidth::ALL {
            let pq = ProductQuantizer::train(&data, dim, &width.pq_params(m)).unwrap();
            let codes = pq.encode(&data).unwrap();
            let packed = PackedCodes::pack(&codes, m, width).unwrap();
            for backend in available_backends() {
                let params = FastScanParams {
                    backend,
                    rerank: true,
                    reservoir_factor: 16,
                };
                for qi in 0..5 {
                    let q = &data[qi * dim..(qi + 1) * dim];
                    let luts = pq.compute_luts(q);
                    let (d_base, _) = search_adc(&pq, &luts, &codes, None, 5);
                    let (d_fast, _) = search_fastscan(&pq, &packed, q, 5, &params, None);
                    for r in 0..5 {
                        assert!(
                            (d_base[r] - d_fast[r]).abs() < 1e-4 * (1.0 + d_base[r].abs()),
                            "{width} {backend:?} q{qi} rank {r}: {} vs {}",
                            d_base[r],
                            d_fast[r]
                        );
                    }
                }
            }
        }
    }

    fn setup(n: usize, dim: usize, m: usize, seed: u64) -> (ProductQuantizer, Vec<f32>, Vec<u8>) {
        let mut rng = Rng::new(seed);
        let data: Vec<f32> = (0..n * dim).map(|_| rng.next_gaussian()).collect();
        let pq = ProductQuantizer::train(&data, dim, &PqParams::new_4bit(m)).unwrap();
        let codes = pq.encode(&data).unwrap();
        (pq, data, codes)
    }

    /// The central correctness property: the SIMD kernel's quantized
    /// distances equal the scalar sum of quantized table entries, for every
    /// backend, including odd M and partial blocks.
    #[test]
    fn kernel_matches_scalar_quantized_sum() {
        let mut rng = Rng::new(31);
        for &(n, m) in &[(32usize, 2usize), (100, 8), (33, 16), (64, 5), (7, 3), (256, 32)] {
            let codes: Vec<u8> = (0..n * m).map(|_| (rng.next_u32() % 16) as u8).collect();
            let luts_f32: Vec<f32> = (0..m * 16).map(|_| rng.next_f32() * 9.0).collect();
            let qluts = QuantizedLuts::from_f32(&luts_f32, m, 16);
            let packed = PackedCodes::pack(&codes, m, CodeWidth::W4).unwrap();
            let kluts = KernelLuts::build(&qluts, packed.lut_rows);
            for backend in available_backends() {
                let got = fastscan_distances_all(&packed, &kluts, backend);
                for i in 0..n {
                    let expect: u16 = (0..m)
                        .map(|q| qluts.row(q)[codes[i * m + q] as usize] as u16)
                        .sum();
                    assert_eq!(got[i], expect, "n={n} m={m} i={i} {backend:?}");
                }
            }
        }
    }

    #[test]
    fn backends_agree_exactly() {
        let backends = available_backends();
        if backends.len() < 2 {
            eprintln!("single backend host; skipping cross-check");
            return;
        }
        let mut rng = Rng::new(32);
        for _ in 0..20 {
            let n = 1 + rng.below(200);
            let m = 2 * (1 + rng.below(16));
            let codes: Vec<u8> = (0..n * m).map(|_| (rng.next_u32() % 16) as u8).collect();
            let luts_f32: Vec<f32> = (0..m * 16).map(|_| rng.next_f32() * 5.0).collect();
            let qluts = QuantizedLuts::from_f32(&luts_f32, m, 16);
            let packed = PackedCodes::pack(&codes, m, CodeWidth::W4).unwrap();
            let kluts = KernelLuts::build(&qluts, packed.lut_rows);
            let a = fastscan_distances_all(&packed, &kluts, backends[0]);
            let b = fastscan_distances_all(&packed, &kluts, backends[1]);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn reservoir_scan_matches_full_distances() {
        let (pq, data, codes) = setup(300, 32, 8, 33);
        let packed = PackedCodes::pack(&codes, 8, CodeWidth::W4).unwrap();
        let luts_f32 = pq.compute_luts(&data[..32]);
        let qluts = QuantizedLuts::from_f32(&luts_f32, 8, 16);
        let kluts = KernelLuts::build(&qluts, packed.lut_rows);
        for backend in available_backends() {
            let all = fastscan_distances_all(&packed, &kluts, backend);
            let mut res = U16Reservoir::new(5, 4);
            scan_into_reservoir(&packed, &kluts, backend, None, &mut res);
            let cands = res.into_candidates();
            let mut sorted = all.clone();
            sorted.sort_unstable();
            let kth = sorted[4];
            for (i, &d) in all.iter().enumerate() {
                if d < kth {
                    assert!(
                        cands.iter().any(|&(cd, cl)| cl == i as i64 && cd == d),
                        "missing strict candidate {i} ({backend:?})"
                    );
                }
            }
        }
    }

    #[test]
    fn reranked_search_matches_adc_baseline() {
        // Paper Fig. 2: 4-bit PQ achieves the *same accuracy* as original
        // PQ (same K=16 codes). With re-ranking the results must agree on
        // distances (labels may differ on exact ties).
        let (pq, data, codes) = setup(500, 32, 16, 34);
        let packed = PackedCodes::pack(&codes, 16, CodeWidth::W4).unwrap();
        for qi in 0..10 {
            let q = &data[qi * 32..(qi + 1) * 32];
            let luts = pq.compute_luts(q);
            let (d_base, _l_base) = search_adc(&pq, &luts, &codes, None, 10);
            let (d_fast, _l_fast) = search_fastscan(
                &pq,
                &packed,
                q,
                10,
                &FastScanParams::default(),
                None,
            );
            for r in 0..10 {
                assert!(
                    (d_base[r] - d_fast[r]).abs() < 1e-4 * (1.0 + d_base[r].abs()),
                    "query {qi} rank {r}: {} vs {}",
                    d_base[r],
                    d_fast[r]
                );
            }
        }
    }

    #[test]
    fn unreranked_search_within_quantization_error() {
        let (pq, data, codes) = setup(400, 16, 4, 35);
        let packed = PackedCodes::pack(&codes, 4, CodeWidth::W4).unwrap();
        let q = &data[..16];
        let luts = pq.compute_luts(q);
        let qluts = QuantizedLuts::from_f32(&luts, 4, 16);
        let (d_base, _) = search_adc(&pq, &luts, &codes, None, 1);
        let mut params = FastScanParams::default();
        params.rerank = false;
        let (d_fast, _) = search_fastscan(&pq, &packed, q, 1, &params, None);
        assert!(
            (d_base[0] - d_fast[0]).abs() <= qluts.max_abs_error() + 1e-3,
            "{} vs {} (bound {})",
            d_base[0],
            d_fast[0],
            qluts.max_abs_error()
        );
    }

    #[test]
    fn external_labels_roundtrip() {
        let (pq, data, codes) = setup(100, 16, 4, 36);
        let packed = PackedCodes::pack(&codes, 4, CodeWidth::W4).unwrap();
        let ext: Vec<i64> = (0..100).map(|i| 7000 + i as i64).collect();
        let (_d, labels) = search_fastscan(
            &pq,
            &packed,
            &data[..16],
            5,
            &FastScanParams::default(),
            Some(&ext),
        );
        assert!(labels.iter().all(|&l| (7000..7100).contains(&l)));
    }

    #[test]
    fn identical_distances_to_exact_adc_decoded() {
        // fastscan + rerank distances must match exact ADC distances for
        // the same labels.
        let (pq, data, codes) = setup(200, 24, 6, 37);
        let packed = PackedCodes::pack(&codes, 6, CodeWidth::W4).unwrap();
        let q = &data[5 * 24..6 * 24];
        let luts = pq.compute_luts(q);
        let all = adc_distances_all(&pq, &luts, &codes);
        let (d, l) = search_fastscan(&pq, &packed, q, 8, &FastScanParams::default(), None);
        for r in 0..8 {
            assert!((all[l[r] as usize] - d[r]).abs() < 1e-5, "rank {r}");
        }
    }

    #[test]
    fn single_vector_database() {
        let (pq, data, codes) = setup(17, 16, 4, 38); // train needs >= 16
        let one = &codes[..4];
        let packed = PackedCodes::pack(one, 4, CodeWidth::W4).unwrap();
        let (d, l) = search_fastscan(&pq, &packed, &data[..16], 3, &FastScanParams::default(), None);
        assert_eq!(l[0], 0);
        assert_eq!(l[1], -1);
        assert!(d[0].is_finite());
    }

    /// Regression: duplicate external labels used to collapse in a
    /// label→position HashMap during re-ranking (and a missing label
    /// panicked via `pos_of[&label]`). Positions now flow through the
    /// reservoir, so duplicates must re-rank each underlying vector
    /// independently and return valid results.
    #[test]
    fn duplicate_external_labels_rerank_safely() {
        let (pq, data, codes) = setup(100, 16, 4, 39);
        let packed = PackedCodes::pack(&codes, 4, CodeWidth::W4).unwrap();
        // every pair of positions shares one label: 50 distinct labels
        let ext: Vec<i64> = (0..100).map(|i| 5000 + (i as i64 / 2)).collect();
        for rerank in [true, false] {
            let mut params = FastScanParams::default();
            params.rerank = rerank;
            let (d, l) =
                search_fastscan(&pq, &packed, &data[..16], 10, &params, Some(&ext));
            assert_eq!(l.len(), 10);
            assert!(l.iter().all(|&x| (5000..5050).contains(&x)), "labels {l:?}");
            assert!(d.windows(2).all(|w| w[0] <= w[1]), "unsorted {d:?}");
            assert!(d.iter().all(|x| x.is_finite()));
        }
        // distances must match a rerank run with identity labels position
        // by position (same candidates, only the naming differs)
        let (d_ext, _) = search_fastscan(
            &pq,
            &packed,
            &data[..16],
            10,
            &FastScanParams::default(),
            Some(&ext),
        );
        let (d_id, _) =
            search_fastscan(&pq, &packed, &data[..16], 10, &FastScanParams::default(), None);
        for r in 0..10 {
            assert!((d_ext[r] - d_id[r]).abs() < 1e-6, "rank {r}");
        }
    }

    /// Regression: distances saturated at `u16::MAX` must still produce k
    /// results (the strict `d < threshold` admission starved them). Also
    /// exercises the non-fused fallback: M exceeds the fused kernels'
    /// register budget (`MAX_CHUNKS`).
    #[test]
    fn saturated_distances_fill_reservoir() {
        let m = 2 * MAX_CHUNKS + 2; // 258 sub-quantizers of 255 → acc saturates
        let n = 40;
        let k = 8;
        let qluts = QuantizedLuts {
            m,
            ksub: 16,
            data: vec![255u8; m * 16],
            delta: 1.0,
            total_bias: 0.0,
        };
        let codes = vec![7u8; n * m];
        let packed = PackedCodes::pack(&codes, m, CodeWidth::W4).unwrap();
        let kluts = KernelLuts::build(&qluts, packed.lut_rows);
        for backend in available_backends() {
            let all = fastscan_distances_all(&packed, &kluts, backend);
            assert!(all.iter().all(|&d| d == u16::MAX), "not saturated ({backend:?})");
            let mut res = U16Reservoir::new(k, 4);
            scan_into_reservoir(&packed, &kluts, backend, None, &mut res);
            let cands = res.into_candidates();
            assert!(
                cands.len() >= k,
                "{backend:?}: {} of {k} saturated candidates kept",
                cands.len()
            );
        }
    }

    /// Filter pushdown property, the acceptance criterion at kernel level:
    /// for every width × backend, over partial blocks and odd M, a masked
    /// scan with an everything-fits reservoir returns *exactly* the
    /// admitted positions with their exact quantized distances — i.e.
    /// bit-identical to post-filtering `fastscan_distances_all`.
    #[test]
    fn filtered_scan_matches_postfilter_all_widths() {
        let mut rng = Rng::new(90);
        for width in CodeWidth::ALL {
            for trial in 0..6 {
                let n = 1 + rng.below(300); // partial blocks on purpose
                let m = 1 + rng.below(12); // odd M on purpose
                let (packed, wl, expect) =
                    width_fixture(n, m, width, 900 + trial * 13 + m as u64);
                // ~50% then ~10% admission
                for modulus in [2usize, 10] {
                    let mask = FilterMask::from_fn(n, |pos| pos % modulus == 0);
                    let mut want: Vec<(u16, i64)> = expect
                        .iter()
                        .enumerate()
                        .filter(|(pos, _)| pos % modulus == 0)
                        .map(|(pos, &d)| (d, pos as i64))
                        .collect();
                    want.sort_unstable();
                    for backend in available_backends() {
                        // capacity >= n: nothing is ever pruned, so the
                        // reservoir holds the full admitted set
                        let mut res = U16Reservoir::new(n.max(1), 1);
                        let mut sink = ScanSink::TopK(&mut res);
                        scan_filtered(&packed, &wl.kernel, backend, None, Some(&mask), &mut sink);
                        let mut got = res.into_candidates();
                        got.sort_unstable();
                        assert_eq!(
                            got, want,
                            "{width} trial {trial} n={n} m={m} mod={modulus} {backend:?}"
                        );
                    }
                }
            }
        }
    }

    /// Filtered reservoir pruning still never loses a strictly-better
    /// candidate *within the admitted set*, for every width and backend.
    #[test]
    fn filtered_reservoir_keeps_admitted_topk() {
        let mut rng = Rng::new(91);
        for width in CodeWidth::ALL {
            let n = 33 + rng.below(300);
            let m = 1 + rng.below(10);
            let k = 1 + rng.below(6);
            let (packed, wl, expect) = width_fixture(n, m, width, 950 + m as u64);
            let mask = FilterMask::from_fn(n, |pos| pos % 3 != 1);
            let mut admitted: Vec<u16> = expect
                .iter()
                .enumerate()
                .filter(|(pos, _)| mask.passes(*pos))
                .map(|(_, &d)| d)
                .collect();
            admitted.sort_unstable();
            let kth = admitted[(k - 1).min(admitted.len() - 1)];
            for backend in available_backends() {
                let mut res = U16Reservoir::new(k, 4);
                let mut sink = ScanSink::TopK(&mut res);
                scan_filtered(&packed, &wl.kernel, backend, None, Some(&mask), &mut sink);
                let cands = res.into_candidates();
                assert!(cands.len() >= k.min(admitted.len()), "{width} {backend:?}");
                for (pos, &d) in expect.iter().enumerate() {
                    if mask.passes(pos) && d < kth {
                        assert!(
                            cands.iter().any(|&(cd, cl)| cl == pos as i64 && cd == d),
                            "{width} {backend:?}: lost admitted candidate {pos}"
                        );
                    }
                    if !mask.passes(pos) {
                        assert!(
                            cands.iter().all(|&(_, cl)| cl != pos as i64),
                            "{width} {backend:?}: filtered position {pos} leaked through"
                        );
                    }
                }
            }
        }
    }

    /// Range sink: the scan must collect exactly the positions with
    /// quantized distance <= bound, on every width and backend — including
    /// the bound == u16::MAX saturation case a strict compare can't express.
    #[test]
    fn range_scan_collects_exact_set() {
        let mut rng = Rng::new(92);
        for width in CodeWidth::ALL {
            let n = 1 + rng.below(300);
            let m = 1 + rng.below(10);
            let (packed, wl, expect) = width_fixture(n, m, width, 970 + m as u64);
            let mut sorted = expect.clone();
            sorted.sort_unstable();
            for bound in [sorted[n / 10], sorted[n / 2], u16::MAX] {
                let want: Vec<(u16, i64)> = {
                    let mut v: Vec<(u16, i64)> = expect
                        .iter()
                        .enumerate()
                        .filter(|(_, &d)| d <= bound)
                        .map(|(pos, &d)| (d, pos as i64))
                        .collect();
                    v.sort_unstable();
                    v
                };
                for backend in available_backends() {
                    let mut hits = Vec::new();
                    let mut sink = ScanSink::Range { bound, hits: &mut hits };
                    scan_filtered(&packed, &wl.kernel, backend, None, None, &mut sink);
                    hits.sort_unstable();
                    assert_eq!(hits, want, "{width} bound={bound} {backend:?}");
                }
            }
        }
    }

    /// Edge cases: an all-zero filter yields nothing (blocks skipped), an
    /// all-ones filter is identical to no filter.
    #[test]
    fn empty_and_full_filters() {
        let (packed, wl, _) = width_fixture(100, 8, CodeWidth::W4, 980);
        let none = FilterMask::from_fn(100, |_| false);
        let all = FilterMask::from_fn(100, |_| true);
        assert_eq!(none.pass_count(), 0);
        assert_eq!(all.pass_count(), 100);
        assert_eq!(all.selectivity(), 1.0);
        for backend in available_backends() {
            let mut res = U16Reservoir::new(5, 4);
            let mut sink = ScanSink::TopK(&mut res);
            scan_filtered(&packed, &wl.kernel, backend, None, Some(&none), &mut sink);
            assert!(res.into_candidates().is_empty(), "{backend:?}");

            let mut res_all = U16Reservoir::new(5, 4);
            let mut sink = ScanSink::TopK(&mut res_all);
            scan_filtered(&packed, &wl.kernel, backend, None, Some(&all), &mut sink);
            let mut with_full = res_all.into_candidates();
            let mut res_bare = U16Reservoir::new(5, 4);
            scan_into_reservoir(&packed, &wl.kernel, backend, None, &mut res_bare);
            let mut without = res_bare.into_candidates();
            with_full.sort_unstable();
            without.sort_unstable();
            assert_eq!(with_full, without, "{backend:?}");
        }
    }

    /// End-to-end range search with re-ranking: exact boundary against the
    /// exact ADC distances, filtered and unfiltered.
    #[test]
    fn range_search_exact_boundary_with_rerank() {
        let (pq, data, codes) = setup(400, 32, 8, 45);
        let packed = PackedCodes::pack(&codes, 8, CodeWidth::W4).unwrap();
        let q = &data[..32];
        let luts = pq.compute_luts(q);
        let all = adc_distances_all(&pq, &luts, &codes);
        let mut sorted = all.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let radius = sorted[40]; // ~10%
        for backend in available_backends() {
            let params = FastScanParams { backend, rerank: true, reservoir_factor: 8 };
            let hits = range_fastscan_with_luts(&pq, &packed, &luts, radius, &params, None, None);
            let want = all.iter().filter(|&&d| d <= radius).count();
            assert_eq!(hits.len(), want, "{backend:?}");
            assert!(hits.windows(2).all(|w| w[0].0 <= w[1].0), "{backend:?}");
            for &(d, l) in &hits {
                assert_eq!(d, all[l as usize], "{backend:?}");
            }
            // filtered range ≡ post-filtered range
            let mask = FilterMask::from_fn(400, |pos| pos % 2 == 0);
            let fhits =
                range_fastscan_with_luts(&pq, &packed, &luts, radius, &params, None, Some(&mask));
            let fwant: Vec<(f32, i64)> =
                hits.iter().copied().filter(|&(_, l)| l % 2 == 0).collect();
            assert_eq!(fhits, fwant, "{backend:?}");
        }
    }

    /// Property test: the fused reservoir scans (portable, SSSE3, NEON —
    /// whichever the host offers) agree with `fastscan_distances_all` +
    /// scalar top-k on random partial blocks (n not divisible by 32,
    /// odd M): every strictly-better-than-kth distance must be collected.
    #[test]
    fn fused_reservoir_scans_match_full_distances_property() {
        let mut rng = Rng::new(40);
        for trial in 0..25 {
            let n = 1 + rng.below(300); // frequently n % 32 != 0
            let m = 1 + rng.below(20); // both odd and even M
            let k = 1 + rng.below(8);
            let codes: Vec<u8> = (0..n * m).map(|_| (rng.next_u32() % 16) as u8).collect();
            let luts_f32: Vec<f32> = (0..m * 16).map(|_| rng.next_f32() * 9.0).collect();
            let qluts = QuantizedLuts::from_f32(&luts_f32, m, 16);
            let packed = PackedCodes::pack(&codes, m, CodeWidth::W4).unwrap();
            let kluts = KernelLuts::build(&qluts, packed.lut_rows);
            for backend in available_backends() {
                let all = fastscan_distances_all(&packed, &kluts, backend);
                // scalar reference top-k threshold
                let mut sorted = all.clone();
                sorted.sort_unstable();
                let kth = sorted[(k - 1).min(n - 1)];
                let mut res = U16Reservoir::new(k, 4);
                scan_into_reservoir(&packed, &kluts, backend, None, &mut res);
                let cands = res.into_candidates();
                assert!(
                    cands.len() >= k.min(n),
                    "trial {trial} {backend:?}: {} results for k={k}, n={n}",
                    cands.len()
                );
                for (i, &d) in all.iter().enumerate() {
                    if d < kth {
                        assert!(
                            cands.iter().any(|&(cd, cl)| cl == i as i64 && cd == d),
                            "trial {trial} {backend:?} n={n} m={m} k={k}: \
                             lost strict candidate {i} (d={d}, kth={kth})"
                        );
                    }
                }
                // every reported candidate's distance must be exact
                for &(cd, cl) in &cands {
                    assert_eq!(cd, all[cl as usize], "trial {trial} {backend:?}");
                }
            }
        }
    }
}
