//! Product quantization: training, encoding, lookup tables, and the two
//! scan kernels compared in the paper's Fig. 2.
//!
//! * [`codebook`] — `ProductQuantizer`: split vectors into `M` sub-vectors,
//!   k-means each sub-space into `K` codewords (paper §2, Eq. 1).
//! * [`adc`] — the **baseline**: asymmetric distance computation via an
//!   in-memory f32 lookup table (paper Eq. 3 / Fig. 1a). This is "original
//!   PQ" in Fig. 2.
//! * [`lut`] — scalar quantization of the f32 table to u8 with a shared
//!   scale/bias, producing `T_SIMD` (paper Eq. 4).
//! * [`layout`] — the 4-bit interleaved block layout: 32 database vectors
//!   per block, sub-quantizer pairs packed so one 32-byte load feeds the
//!   dual-lane shuffle ("we must carefully maintain the code layout", §3).
//! * [`fastscan`] — the **4-bit PQ kernel**: register-resident LUTs, dual
//!   `vqtbl1q_u8` shuffle per pair, saturating u16 accumulation
//!   (paper §3 / Fig. 1c), plus the optional exact re-ranking pass.

pub mod adc;
pub mod codebook;
pub mod fastscan;
pub mod layout;
pub mod lut;

pub use adc::search_adc;
pub use codebook::{PqParams, ProductQuantizer};
pub use fastscan::{search_fastscan, FastScanParams};
pub use layout::PackedCodes4;
pub use lut::QuantizedLuts;

/// Number of database vectors per fastscan block ("bbs" in faiss).
/// 32 = one virtual 256-bit register of 4-bit codes per sub-quantizer pair.
pub const BLOCK_SIZE: usize = 32;
