//! Product quantization: training, encoding, lookup tables, and the
//! multi-bitwidth fastscan subsystem.
//!
//! The scan stack is a **width × backend matrix**: every code width rides
//! the same dual-lane 16-entry shuffle primitive, and every backend
//! implements that primitive on its own hardware.
//!
//! | width ([`bitwidth::CodeWidth`]) | codes | table form | cost vs 4-bit | role |
//! |------|------|------------|------|------|
//! | `W2` | K=4, 2 bits | adjacent pairs fused into 16-entry sum-tables (Quicker ADC grouping) | ~0.5× | faster / coarser |
//! | `W4` | K=16, 4 bits | one 16-entry table per sub-quantizer | 1× | the paper's kernel |
//! | `W8` | K=256 product-structured, 8 bits | paired lo/hi nibble half-space tables | ~2× | slower / finer |
//!
//! | backend ([`crate::simd::Backend`]) | shuffle | runs on |
//! |------|------|------|
//! | `Portable` | scalar model of `vqtbl1q_u8` | anywhere (semantic reference) |
//! | `Ssse3` | `pshufb` | x86_64 |
//! | `Neon` | `vqtbl1q_u8` | aarch64 (the paper's target) |
//!
//! All nine combinations are differential-tested: each width's three
//! backends must produce bit-identical reservoir contents.
//!
//! Modules:
//!
//! * [`codebook`] — `ProductQuantizer`: split vectors into `M` sub-vectors,
//!   k-means each sub-space into `K` codewords (paper §2, Eq. 1).
//! * [`adc`] — the **baseline**: asymmetric distance computation via an
//!   in-memory f32 lookup table (paper Eq. 3 / Fig. 1a). This is "original
//!   PQ" in Fig. 2.
//! * [`lut`] — scalar quantization of the f32 table to u8 with a shared
//!   scale/bias, producing `T_SIMD` (paper Eq. 4).
//! * [`bitwidth`] — the width axis: [`bitwidth::CodeWidth`] geometry,
//!   width-aware quantized-table construction (2-bit fusing, 8-bit
//!   half-space rows).
//! * [`layout`] — the width-parametric interleaved block layout: 32
//!   database vectors per block, code chunks packed so one 32-byte load
//!   feeds the dual-lane shuffle ("we must carefully maintain the code
//!   layout", §3).
//! * [`fastscan`] — the kernel matrix: register-resident LUTs, dual
//!   `vqtbl1q_u8` shuffle per chunk wired per width
//!   ([`fastscan::LaneWiring`]), saturating u16 accumulation
//!   (paper §3 / Fig. 1c), plus the optional exact re-ranking pass.

pub mod adc;
pub mod bitwidth;
pub mod codebook;
pub mod fastscan;
pub mod layout;
pub mod lut;

pub use adc::search_adc;
pub use bitwidth::CodeWidth;
pub use codebook::{PqParams, ProductQuantizer};
pub use fastscan::{search_fastscan, FastScanParams};
pub use layout::PackedCodes;
pub use lut::QuantizedLuts;

/// Number of database vectors per fastscan block ("bbs" in faiss).
/// 32 = one virtual 256-bit register of codes per chunk.
pub const BLOCK_SIZE: usize = 32;
