//! Scalar quantization of the f32 ADC tables to u8 — producing `T_SIMD`
//! (paper §2, Eq. 4).
//!
//! The 16-entry tables must fit a 128-bit register, so each f32 entry is
//! mapped to one unsigned byte:
//!
//! ```text
//!   qT[m][k] = round((T[m][k] − bias_m) / Δ)   clamped to 0..255
//! ```
//!
//! with per-sub-quantizer bias `bias_m = min_k T[m][k]` (so every table
//! starts at 0 and the u8 dynamic range is not wasted on the common offset)
//! and one global scale `Δ` chosen so the *largest* per-table range still
//! fits (faiss `quantize_LUT` uses the same shape). The accumulated u16
//! distance is decoded back with `f(D) = Δ·D + Σ_m bias_m` — the paper's
//! "reconstruction of an unsigned char to float, which is trivial".

/// u8-quantized lookup tables plus the affine decode parameters.
#[derive(Clone, Debug)]
pub struct QuantizedLuts {
    pub m: usize,
    pub ksub: usize,
    /// `m × ksub` quantized entries (row per sub-quantizer).
    pub data: Vec<u8>,
    /// Global scale Δ.
    pub delta: f32,
    /// Σ_m bias_m.
    pub total_bias: f32,
}

impl QuantizedLuts {
    /// Quantize f32 LUTs (`m × ksub`, from
    /// [`crate::pq::ProductQuantizer::compute_luts`]).
    pub fn from_f32(luts: &[f32], m: usize, ksub: usize) -> Self {
        Self::from_f32_reuse(luts, m, ksub, Vec::new())
    }

    /// [`QuantizedLuts::from_f32`] on recycled `data` storage (cleared and
    /// resized; capacity kept) — the executor's scratch path. Per-row
    /// biases are recomputed in the fill pass instead of staged in a
    /// temporary, so a warmed-up buffer quantizes with zero allocations;
    /// the arithmetic (and thus every quantized byte) is identical to the
    /// allocating form.
    pub fn from_f32_reuse(luts: &[f32], m: usize, ksub: usize, mut data: Vec<u8>) -> Self {
        debug_assert_eq!(luts.len(), m * ksub);
        let mut max_range = 0.0f32;
        for mi in 0..m {
            let row = &luts[mi * ksub..(mi + 1) * ksub];
            let lo = row.iter().cloned().fold(f32::INFINITY, f32::min);
            let hi = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            max_range = max_range.max(hi - lo);
        }
        // Δ such that the widest row maps onto 0..=255. Degenerate case
        // (all-constant tables): Δ=1 keeps decode exact.
        let delta = if max_range > 0.0 { max_range / 255.0 } else { 1.0 };
        let inv = 1.0 / delta;
        data.clear();
        data.resize(m * ksub, 0);
        let mut total_bias = 0.0f32;
        for mi in 0..m {
            let row = &luts[mi * ksub..(mi + 1) * ksub];
            let bias = row.iter().cloned().fold(f32::INFINITY, f32::min);
            for k in 0..ksub {
                let q = ((row[k] - bias) * inv).round();
                data[mi * ksub + k] = q.clamp(0.0, 255.0) as u8;
            }
            total_bias += bias;
        }
        Self { m, ksub, data, delta, total_bias }
    }

    /// Quantized table row for sub-quantizer `mi` (`ksub` bytes — for
    /// `ksub = 16` exactly one 128-bit register, the paper's `T_SIMD`).
    #[inline]
    pub fn row(&self, mi: usize) -> &[u8] {
        &self.data[mi * self.ksub..(mi + 1) * self.ksub]
    }

    /// Decode an accumulated u16 distance back to (approximate) f32.
    #[inline]
    pub fn decode(&self, acc: u16) -> f32 {
        self.delta * acc as f32 + self.total_bias
    }

    /// Quantize an f32 distance *bound* into the accumulator domain,
    /// rounding down (safe for pruning: never rejects a true candidate).
    #[inline]
    pub fn encode_bound(&self, d: f32) -> u16 {
        let q = (d - self.total_bias) / self.delta;
        if q <= 0.0 {
            0
        } else if q >= u16::MAX as f32 {
            u16::MAX
        } else {
            q.floor() as u16
        }
    }

    /// Worst-case decode error of one accumulated distance: each of the `m`
    /// table entries is off by at most Δ/2.
    pub fn max_abs_error(&self) -> f32 {
        0.5 * self.delta * self.m as f32
    }

    /// Quantized collection bound for a range query with radius `radius`:
    /// admit accumulated distances `<= bound`. With re-ranking the bound
    /// is widened by the worst-case decode error (plus one count for
    /// float rounding in the bound itself) so no true hit is pruned by
    /// quantization — the exact pass trims the over-collection; without
    /// re-ranking the decoded quantized distance IS the result, so the
    /// bound is the radius itself. THE single definition shared by the
    /// flat and IVF range paths, so they cannot disagree at the boundary.
    #[inline]
    pub fn collection_bound(&self, radius: f32, rerank: bool) -> u16 {
        if rerank {
            self.encode_bound(radius + self.max_abs_error()).saturating_add(1)
        } else {
            self.encode_bound(radius)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_luts(m: usize, ksub: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..m * ksub).map(|_| rng.next_f32() * scale + 3.0).collect()
    }

    #[test]
    fn quantize_decode_error_bounded() {
        let m = 16;
        let ksub = 16;
        let luts = random_luts(m, ksub, 21, 4.0);
        let q = QuantizedLuts::from_f32(&luts, m, ksub);
        // accumulate a random assignment of codes and compare against f32
        let mut rng = Rng::new(22);
        for _ in 0..200 {
            let codes: Vec<usize> = (0..m).map(|_| rng.below(ksub)).collect();
            let exact: f32 = (0..m).map(|mi| luts[mi * ksub + codes[mi]]).sum();
            let acc: u16 = (0..m).map(|mi| q.row(mi)[codes[mi]] as u16).sum();
            let approx = q.decode(acc);
            assert!(
                (exact - approx).abs() <= q.max_abs_error() + 1e-4,
                "exact {exact} approx {approx} bound {}",
                q.max_abs_error()
            );
        }
    }

    #[test]
    fn min_entry_is_zero_per_row() {
        let luts = random_luts(8, 16, 23, 10.0);
        let q = QuantizedLuts::from_f32(&luts, 8, 16);
        for mi in 0..8 {
            assert_eq!(*q.row(mi).iter().min().unwrap(), 0, "row {mi}");
        }
    }

    #[test]
    fn widest_row_spans_255() {
        let luts = random_luts(8, 16, 24, 6.0);
        let q = QuantizedLuts::from_f32(&luts, 8, 16);
        let max_entry = q.data.iter().cloned().max().unwrap();
        assert_eq!(max_entry, 255);
    }

    #[test]
    fn constant_tables_degenerate() {
        let luts = vec![5.0f32; 4 * 16];
        let q = QuantizedLuts::from_f32(&luts, 4, 16);
        assert!(q.data.iter().all(|&b| b == 0));
        assert_eq!(q.decode(0), 20.0); // 4 × bias 5.0
    }

    #[test]
    fn encode_bound_is_conservative() {
        let luts = random_luts(16, 16, 25, 8.0);
        let q = QuantizedLuts::from_f32(&luts, 16, 16);
        let mut rng = Rng::new(26);
        for _ in 0..200 {
            let codes: Vec<usize> = (0..16).map(|_| rng.below(16)).collect();
            let acc: u16 = (0..16).map(|mi| q.row(mi)[codes[mi]] as u16).sum();
            let d = q.decode(acc);
            // encoding the decoded value back must not exceed acc
            assert!(q.encode_bound(d) <= acc + 1);
            // a bound below the bias maps to 0
            assert_eq!(q.encode_bound(q.total_bias - 1.0), 0);
        }
    }

    #[test]
    fn monotonic_in_acc() {
        let luts = random_luts(8, 16, 27, 2.0);
        let q = QuantizedLuts::from_f32(&luts, 8, 16);
        assert!(q.decode(10) < q.decode(11));
        assert!(q.decode(0) >= q.total_bias - 1e-6);
    }
}
