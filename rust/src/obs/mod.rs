//! Observability: per-query trace spans for the query pipeline.
//!
//! The paper's speedup claim is a claim about *where microseconds go* —
//! LUT build vs. shuffle scan vs. rerank — so the serving stack needs a
//! way to attribute a query's latency to its phases without perturbing
//! the thing it measures. This module is that facility; the coordinator
//! layers histograms, a slow-query log and Prometheus exposition on top
//! (see `coordinator/metrics.rs`).
//!
//! # Span lifecycle
//!
//! Every pooled [`ScanScratch`](crate::exec::ScanScratch) carries one
//! [`TraceBuf`]: a fixed inline array of per-[`Phase`] accumulator slots
//! (wall µs, a unit count, bytes touched). The query path drives it:
//!
//! 1. A traced request (`QueryRequest { trace: true, .. }`) calls
//!    [`TraceBuf::enable`] at the top of its per-query closure. Pooled
//!    scratches start (and are always returned) disabled, so a previous
//!    query's flag can never leak into the next checkout.
//! 2. Instrumented phases bracket themselves with [`TraceBuf::start`] /
//!    [`TraceBuf::finish_with`], or fold externally measured costs in
//!    via [`TraceBuf::add`]. Phases are *non-overlapping leaves*: the
//!    scan kernels record under the ambient [`TraceBuf::scan_phase`]
//!    label (`ListScan` for IVF/flat regions, `SegmentScan` for sealed
//!    segment units) so the same kernel code attributes correctly from
//!    every caller and nothing is double-counted.
//! 3. At the end of the query, [`TraceBuf::drain`] snapshots the
//!    non-empty slots into `Vec<TraceSpan>` (in [`Phase::ALL`] order),
//!    zeroes the buffer and **disables it** — re-arming the scratch for
//!    pool reuse.
//!
//! The [`Phase::Total`] span brackets the whole per-query execution, so
//! `phase_sum_us(spans) ≈ total` holds whenever the phases run serially.
//! Parallel fan-out (IVF multi-list) records its scan as one wall-clock
//! span around the fork/join, keeping the identity; the segmented index
//! takes its serial unit walk when traced for the same reason.
//!
//! # Overhead contract
//!
//! Tracing must be free when off and cheap when on:
//!
//! * **Off (steady state):** no timestamps — [`SpanTimer`] holds
//!   `Option<Instant>` and `start` returns `None` without touching the
//!   clock — and no allocation: the slots live inline in the scratch,
//!   so the PR 5 no-allocation guarantee is untouched (asserted by
//!   `obs_trace_off_steady_state_no_alloc`).
//! * **On:** two `Instant::now` calls per phase plus one `Vec` of at
//!   most [`NUM_PHASES`] spans per query at drain time.
//! * **Always:** results are bit-identical with tracing on or off — the
//!   trace observes admission decisions, it never feeds back into them
//!   (differential-tested across backend × width × kind).

use std::time::Instant;

/// Pipeline phases a query's wall time is attributed to. The set mirrors
/// the paper's cost decomposition (Fig. 2): table construction, coarse
/// quantization, the SIMD scan itself, and the float rerank tail.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Request-level plan work: param resolution, filter mask planning,
    /// nprobe escalation. Amortized per query when a batch shares it.
    PlanCompile,
    /// Coarse quantizer assignment (query → probed IVF lists).
    CoarseQuant,
    /// Float LUT computation plus u8 quantization/packing for the
    /// kernel (the paper's "table construction" cost).
    LutBuild,
    /// SIMD scan over flat or per-probed-list packed code regions.
    ListScan,
    /// SIMD scan over sealed segment code regions.
    SegmentScan,
    /// Memtable (unsealed rows) scan in the segmented index.
    MemtableScan,
    /// Candidate merging across probed lists / scan units / shards.
    Merge,
    /// Exact-distance rerank of surviving candidates.
    Rerank,
    /// The whole per-query execution; phases above are its leaves.
    Total,
}

/// Number of distinct phases (the size of a [`TraceBuf`]'s slot array).
pub const NUM_PHASES: usize = 9;

impl Phase {
    /// Every phase, in canonical (pipeline) order.
    pub const ALL: [Phase; NUM_PHASES] = [
        Phase::PlanCompile,
        Phase::CoarseQuant,
        Phase::LutBuild,
        Phase::ListScan,
        Phase::SegmentScan,
        Phase::MemtableScan,
        Phase::Merge,
        Phase::Rerank,
        Phase::Total,
    ];

    /// Stable snake_case name used on the wire and as the Prometheus
    /// `phase` label value.
    pub fn name(self) -> &'static str {
        match self {
            Phase::PlanCompile => "plan_compile",
            Phase::CoarseQuant => "coarse_quant",
            Phase::LutBuild => "lut_build",
            Phase::ListScan => "list_scan",
            Phase::SegmentScan => "segment_scan",
            Phase::MemtableScan => "memtable_scan",
            Phase::Merge => "merge",
            Phase::Rerank => "rerank",
            Phase::Total => "total",
        }
    }

    /// Inverse of [`Phase::name`] (wire parsing).
    pub fn from_name(s: &str) -> Option<Phase> {
        Phase::ALL.into_iter().find(|p| p.name() == s)
    }

    /// Dense index into per-phase arrays ([`NUM_PHASES`] slots, canonical
    /// order) — the metrics registry keys its phase histograms with this.
    #[inline]
    pub fn idx(self) -> usize {
        match self {
            Phase::PlanCompile => 0,
            Phase::CoarseQuant => 1,
            Phase::LutBuild => 2,
            Phase::ListScan => 3,
            Phase::SegmentScan => 4,
            Phase::MemtableScan => 5,
            Phase::Merge => 6,
            Phase::Rerank => 7,
            Phase::Total => 8,
        }
    }
}

/// One completed phase of one query: wall time plus the phase's natural
/// cost counters (codes scanned, candidates merged, …) and the mapped
/// bytes the phase touched.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceSpan {
    pub phase: Phase,
    /// Wall-clock microseconds attributed to the phase.
    pub us: u64,
    /// Phase-specific unit count (codes scanned, lists probed,
    /// candidates reranked…); 0 when the phase has no natural unit.
    pub count: u64,
    /// Mapped code bytes the phase walked (0 for heap-backed regions).
    pub bytes: u64,
}

#[derive(Clone, Copy, Debug, Default)]
struct Slot {
    us: u64,
    count: u64,
    bytes: u64,
    hit: bool,
}

/// Per-scratch span accumulator. Inline, fixed-size, allocation-free;
/// see the module docs for the lifecycle and overhead contract.
#[derive(Clone, Debug)]
pub struct TraceBuf {
    on: bool,
    scan_phase: Phase,
    slots: [Slot; NUM_PHASES],
}

impl Default for TraceBuf {
    fn default() -> Self {
        TraceBuf { on: false, scan_phase: Phase::ListScan, slots: [Slot::default(); NUM_PHASES] }
    }
}

impl TraceBuf {
    /// Is tracing armed for the current query?
    #[inline]
    pub fn enabled(&self) -> bool {
        self.on
    }

    /// Arm tracing for the current query, clearing any stale slots.
    pub fn enable(&mut self) {
        self.slots = [Slot::default(); NUM_PHASES];
        self.scan_phase = Phase::ListScan;
        self.on = true;
    }

    /// Label the next kernel-level scan spans record under (`ListScan`
    /// by default; the segmented index sets `SegmentScan` for sealed
    /// units so shared scan code attributes correctly).
    #[inline]
    pub fn set_scan_phase(&mut self, phase: Phase) {
        self.scan_phase = phase;
    }

    /// The ambient label for kernel-level scan spans.
    #[inline]
    pub fn scan_phase(&self) -> Phase {
        self.scan_phase
    }

    /// Disarm without snapshotting — the pool's check-in safety net for
    /// error paths that bailed before draining (stale slots are cleared
    /// by the next [`TraceBuf::enable`]).
    #[inline]
    pub fn disarm(&mut self) {
        self.on = false;
        self.scan_phase = Phase::ListScan;
    }

    /// Begin timing a span. When tracing is off this is a no-op that
    /// never reads the clock.
    #[inline]
    pub fn start(&self) -> SpanTimer {
        SpanTimer { t0: if self.on { Some(Instant::now()) } else { None } }
    }

    /// Close a timed span with no counters.
    #[inline]
    pub fn finish(&mut self, phase: Phase, t: SpanTimer) {
        self.finish_with(phase, t, 0, 0);
    }

    /// Close a timed span, folding its elapsed time and counters into
    /// the phase's slot (repeat spans of one phase accumulate).
    #[inline]
    pub fn finish_with(&mut self, phase: Phase, t: SpanTimer, count: u64, bytes: u64) {
        if let Some(t0) = t.t0 {
            self.add(phase, t0.elapsed().as_micros() as u64, count, bytes);
        }
    }

    /// Fold an externally measured cost into a phase (used to amortize
    /// request-level plan work across a batch's queries).
    #[inline]
    pub fn add(&mut self, phase: Phase, us: u64, count: u64, bytes: u64) {
        if !self.on {
            return;
        }
        let s = &mut self.slots[phase.idx()];
        s.us += us;
        s.count += count;
        s.bytes += bytes;
        s.hit = true;
    }

    /// Snapshot the recorded spans (in [`Phase::ALL`] order), reset the
    /// buffer and disable tracing — the scratch goes back to its pool
    /// re-armed for untraced reuse. Returns an empty `Vec` (no
    /// allocation) when tracing was off.
    pub fn drain(&mut self) -> Vec<TraceSpan> {
        if !self.on {
            return Vec::new();
        }
        let mut out = Vec::with_capacity(NUM_PHASES);
        for p in Phase::ALL {
            let s = self.slots[p.idx()];
            if s.hit {
                out.push(TraceSpan { phase: p, us: s.us, count: s.count, bytes: s.bytes });
            }
        }
        self.slots = [Slot::default(); NUM_PHASES];
        self.scan_phase = Phase::ListScan;
        self.on = false;
        out
    }
}

/// In-flight timing handle; `None` when tracing is off so the disabled
/// path never touches the clock.
pub struct SpanTimer {
    t0: Option<Instant>,
}

/// Fold per-shard (or per-unit) span rows into one row by summing each
/// phase's time and counters. Used by the sharded router so a fanned-out
/// query still reports one breakdown.
pub fn merge_spans(rows: &[&[TraceSpan]]) -> Vec<TraceSpan> {
    let mut acc = [Slot::default(); NUM_PHASES];
    for row in rows {
        for sp in *row {
            let s = &mut acc[sp.phase.idx()];
            s.us += sp.us;
            s.count += sp.count;
            s.bytes += sp.bytes;
            s.hit = true;
        }
    }
    Phase::ALL
        .into_iter()
        .filter(|p| acc[p.idx()].hit)
        .map(|p| {
            let s = acc[p.idx()];
            TraceSpan { phase: p, us: s.us, count: s.count, bytes: s.bytes }
        })
        .collect()
}

/// Sum of leaf-phase wall time (everything except [`Phase::Total`]) —
/// the quantity the acceptance criterion compares against the `Total`
/// span.
pub fn phase_sum_us(spans: &[TraceSpan]) -> u64 {
    spans.iter().filter(|s| s.phase != Phase::Total).map(|s| s.us).sum()
}

/// Wall time of the [`Phase::Total`] span, if present.
pub fn total_us(spans: &[TraceSpan]) -> Option<u64> {
    spans.iter().find(|s| s.phase == Phase::Total).map(|s| s.us)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_buf_records_nothing_and_drains_empty() {
        let mut tb = TraceBuf::default();
        assert!(!tb.enabled());
        let t = tb.start();
        assert!(t.t0.is_none(), "disabled start must not read the clock");
        tb.finish_with(Phase::ListScan, t, 100, 100);
        tb.add(Phase::Rerank, 5, 5, 0);
        let spans = tb.drain();
        assert!(spans.is_empty());
        assert_eq!(spans.capacity(), 0, "disabled drain must not allocate");
    }

    #[test]
    fn enabled_buf_accumulates_and_drain_disarms() {
        let mut tb = TraceBuf::default();
        tb.enable();
        tb.add(Phase::LutBuild, 10, 0, 0);
        tb.add(Phase::ListScan, 30, 1000, 4096);
        tb.add(Phase::ListScan, 20, 500, 0); // repeat spans accumulate
        tb.add(Phase::Total, 70, 0, 0);
        let spans = tb.drain();
        assert_eq!(
            spans,
            vec![
                TraceSpan { phase: Phase::LutBuild, us: 10, count: 0, bytes: 0 },
                TraceSpan { phase: Phase::ListScan, us: 50, count: 1500, bytes: 4096 },
                TraceSpan { phase: Phase::Total, us: 70, count: 0, bytes: 0 },
            ]
        );
        assert_eq!(phase_sum_us(&spans), 60);
        assert_eq!(total_us(&spans), Some(70));
        // drained ⇒ disarmed and empty
        assert!(!tb.enabled());
        assert!(tb.drain().is_empty());
    }

    #[test]
    fn zero_us_span_still_surfaces() {
        // A phase that ran but took <1µs must still appear (count carries
        // the information even when the clock rounds to zero).
        let mut tb = TraceBuf::default();
        tb.enable();
        tb.add(Phase::CoarseQuant, 0, 8, 0);
        let spans = tb.drain();
        assert_eq!(spans.len(), 1);
        assert_eq!(spans[0].phase, Phase::CoarseQuant);
        assert_eq!(spans[0].count, 8);
    }

    #[test]
    fn scan_phase_defaults_and_resets() {
        let mut tb = TraceBuf::default();
        assert_eq!(tb.scan_phase(), Phase::ListScan);
        tb.enable();
        tb.set_scan_phase(Phase::SegmentScan);
        assert_eq!(tb.scan_phase(), Phase::SegmentScan);
        tb.add(Phase::SegmentScan, 1, 0, 0);
        tb.drain();
        assert_eq!(tb.scan_phase(), Phase::ListScan, "drain must reset the ambient label");
    }

    #[test]
    fn timer_measures_elapsed_when_enabled() {
        let mut tb = TraceBuf::default();
        tb.enable();
        let t = tb.start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        tb.finish_with(Phase::Rerank, t, 3, 0);
        let spans = tb.drain();
        assert_eq!(spans.len(), 1);
        assert!(spans[0].us >= 1_000, "slept 2ms but recorded {}µs", spans[0].us);
        assert_eq!(spans[0].count, 3);
    }

    #[test]
    fn names_round_trip() {
        for p in Phase::ALL {
            assert_eq!(Phase::from_name(p.name()), Some(p));
        }
        assert_eq!(Phase::from_name("nope"), None);
    }

    #[test]
    fn merge_spans_sums_per_phase() {
        let a = vec![
            TraceSpan { phase: Phase::LutBuild, us: 5, count: 0, bytes: 0 },
            TraceSpan { phase: Phase::SegmentScan, us: 40, count: 100, bytes: 64 },
        ];
        let b = vec![
            TraceSpan { phase: Phase::SegmentScan, us: 60, count: 300, bytes: 128 },
            TraceSpan { phase: Phase::Total, us: 110, count: 0, bytes: 0 },
        ];
        let m = merge_spans(&[&a, &b]);
        assert_eq!(
            m,
            vec![
                TraceSpan { phase: Phase::LutBuild, us: 5, count: 0, bytes: 0 },
                TraceSpan { phase: Phase::SegmentScan, us: 100, count: 400, bytes: 192 },
                TraceSpan { phase: Phase::Total, us: 110, count: 0, bytes: 0 },
            ]
        );
        assert!(merge_spans(&[]).is_empty());
    }
}
