//! Experiment runners: one function per paper table/figure (and per
//! ablation), shared by `cargo bench` targets and the `armpq` CLI.
//!
//! Mapping to the paper (see DESIGN.md §4):
//!
//! | runner                | paper artifact                          |
//! |-----------------------|-----------------------------------------|
//! | [`run_fig2`]          | Fig. 2a/2b — PQ vs 4-bit PQ, recall/QPS |
//! | [`run_table1`]        | Table 1 — IVF+HNSW+PQ16x4fs at scale    |
//! | [`run_kernel_micro`]  | Fig. 1 — per-lookup-op cost comparison  |
//! | [`run_ablation_lut`]  | §2's u8 table quantization              |
//! | [`run_ablation_layout`]| §3's "carefully maintain the layout"   |
//! | [`run_pjrt_e2e`]      | 3-layer composition (repo-specific)     |

use crate::datasets::{Dataset, SyntheticDataset};
use crate::eval::{ground_truth, measure_search, recall_at_r};
use crate::index::{IndexIvfPq4, IndexPq, IndexPq4FastScan, Index};
use crate::pq::{CodeWidth, PqParams};
use crate::simd::{available_backends, Backend};
use crate::storage::OpenOptions;
use crate::util::bench::{black_box, BenchRunner, Table};
use crate::util::timer::Timer;
use crate::Result;

/// Dataset selector for the figure runners (same registry the lab's
/// sweep specs resolve through).
pub fn make_dataset(name: &str, n: usize, nq: usize, seed: u64) -> Dataset {
    SyntheticDataset::by_name(name, n, nq, seed)
        .unwrap_or_else(|| panic!("unknown dataset {name:?} (use sift|deep|gaussian)"))
}

/// Fig. 2: recall@1 vs QPS for original PQ vs 4-bit fastscan PQ, sweeping M.
///
/// K = 16 for both (paper: "each vector takes 4M bits"), so the two systems
/// share codes and accuracy; only the scan differs.
pub fn run_fig2(
    dataset: &str,
    n: usize,
    nq: usize,
    ms: &[usize],
    trials: usize,
    seed: u64,
) -> Result<Table> {
    let ds = make_dataset(dataset, n, nq, seed);
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let mut table = Table::new(
        &format!("Fig2 {dataset} n={n}"),
        &["M", "method", "recall@1", "ms/query", "QPS", "speedup"],
    );
    for &m in ms {
        if ds.dim % m != 0 {
            eprintln!("skipping M={m}: dim {} not divisible", ds.dim);
            continue;
        }
        // --- original PQ (naive in-memory LUT scan) ---
        let mut naive = IndexPq::new(ds.dim, PqParams::new_4bit(m));
        naive.train(&ds.train)?;
        naive.add(&ds.base)?;
        let m_naive = measure_search(&ds.queries, ds.dim, &gt, 1, 1, trials, |q, k| {
            let r = naive.search(q, k, None).unwrap();
            (r.distances, r.labels)
        });

        // --- 4-bit fastscan PQ ---
        let mut fast = IndexPq4FastScan::new(ds.dim, m);
        fast.train(&ds.train)?;
        fast.add(&ds.base)?;
        fast.seal()?;
        let m_fast = measure_search(&ds.queries, ds.dim, &gt, 1, 1, trials, |q, k| {
            let r = fast.search(q, k, None).unwrap();
            (r.distances, r.labels)
        });

        let speedup = m_naive.ms_per_query / m_fast.ms_per_query;
        table.row(vec![
            m.to_string(),
            "PQ (naive)".into(),
            format!("{:.3}", m_naive.recall_at_1),
            format!("{:.3}", m_naive.ms_per_query),
            format!("{:.0}", m_naive.qps),
            "1.0".into(),
        ]);
        table.row(vec![
            m.to_string(),
            "4-bit PQ (SIMD)".into(),
            format!("{:.3}", m_fast.recall_at_1),
            format!("{:.3}", m_fast.ms_per_query),
            format!("{:.0}", m_fast.qps),
            format!("{speedup:.1}"),
        ]);
    }
    Ok(table)
}

/// Table 1: IVF + HNSW coarse + PQ16x4fs on a Deep1B-like dataset
/// (scaled to `n`), sweeping nprobe ∈ {1, 2, 4}.
pub fn run_table1(
    n: usize,
    nq: usize,
    nlist: usize,
    m: usize,
    nprobes: &[usize],
    trials: usize,
    seed: u64,
) -> Result<Table> {
    run_table1_with(n, nq, nlist, m, nprobes, trials, seed, None)
}

/// [`run_table1`] with an explicit storage mode: `Some(mmap)` persists the
/// built index to a v3 file, drops the heap copy, and measures the
/// zero-copy mapped reopen instead — the scan path a larger-than-RAM
/// deployment uses. Zero-copy loads are bit-identical to heap loads, so
/// the recall column is invariant to this knob; only latency moves.
#[allow(clippy::too_many_arguments)]
pub fn run_table1_with(
    n: usize,
    nq: usize,
    nlist: usize,
    m: usize,
    nprobes: &[usize],
    trials: usize,
    seed: u64,
    open: Option<&OpenOptions>,
) -> Result<Table> {
    let ds = SyntheticDataset::deep_like(n, nq, seed);
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let mut idx = IndexIvfPq4::new(ds.dim, nlist, m, true, 32);
    let t_train = Timer::start();
    idx.train(&ds.train)?;
    let train_s = t_train.elapsed_s();
    let t_add = Timer::start();
    idx.add(&ds.base)?;
    idx.seal()?;
    let add_s = t_add.elapsed_s();
    eprintln!("table1: train {train_s:.1}s, add+seal {add_s:.1}s, bits/vec {:.1}", idx.inner().code_bits_per_vector());

    let mapped_file = match open.filter(|o| o.mmap) {
        Some(o) => {
            let path = std::env::temp_dir()
                .join(format!("armpq_table1_{}_{seed}.idx", std::process::id()));
            crate::index::io::save_ivfpq4(idx.inner(), &path)?;
            let reopened = IndexIvfPq4::from_inner(crate::index::io::load_ivfpq4_with(&path, o)?);
            idx = reopened; // the heap-built copy drops here
            eprintln!(
                "table1: mapped reopen of {} ({} B on disk, budget {:?} MiB)",
                path.display(),
                std::fs::metadata(&path)?.len(),
                o.budget_mb
            );
            Some(path)
        }
        None => None,
    };

    let mode = if mapped_file.is_some() { " mmap" } else { "" };
    let mut table = Table::new(
        &format!("Table1 deep-like n={n}{mode}"),
        &["nlist", "nprobe", "M", "K", "recall@1", "ms/query"],
    );
    for &nprobe in nprobes {
        // per-request override: the sealed index itself is never mutated
        let params = crate::index::SearchParams::new().with_nprobe(nprobe);
        let meas = measure_search(&ds.queries, ds.dim, &gt, 1, 1, trials, |q, k| {
            let r = idx.search(q, k, Some(&params)).unwrap();
            (r.distances, r.labels)
        });
        table.row(vec![
            nlist.to_string(),
            nprobe.to_string(),
            m.to_string(),
            "16".into(),
            format!("{:.3}", meas.recall_at_1),
            format!("{:.2}", meas.ms_per_query),
        ]);
    }
    if let Some(path) = mapped_file {
        drop(idx); // unmap before unlinking
        std::fs::remove_file(path).ok();
    }
    Ok(table)
}

/// Thread-scaling curve of the plan/execute layer (the `--threads` axis):
/// batch throughput (queries fan out across workers) and single-query
/// large-`nprobe` latency (probed lists fan out across workers) at each
/// thread count, on one sealed IVF index.
///
/// Each (thread count, mode) cell runs twice — once on the persistent
/// worker pool (`QueryExecutor::new`, the serving default) and once on
/// the legacy per-call scoped-thread path (`new_scoped`) — so the
/// spawn/teardown tax the pool removes is a row-to-row read
/// (`batch/pool` vs `batch/scoped`). The executor guarantees
/// bit-identical results at every thread count on both paths (the
/// `exec_pool_matches_scoped_full_stack` integration test is the
/// differential proof), so the comparison is pure wall-clock: `speedup`
/// is relative to the first thread count in `threads` (conventionally 1)
/// for the same mode+path.
#[allow(clippy::too_many_arguments)]
pub fn run_thread_scaling(
    dataset: &str,
    n: usize,
    nq: usize,
    nlist: usize,
    m: usize,
    width: CodeWidth,
    threads: &[usize],
    trials: usize,
    seed: u64,
) -> Result<Table> {
    use crate::exec::QueryExecutor;
    use crate::index::{QueryRequest, SearchParams};

    let ds = make_dataset(dataset, n, nq, seed);
    let mut idx = IndexIvfPq4::new_width(ds.dim, nlist, m, width, false, 32);
    idx.train(&ds.train)?;
    idx.add(&ds.base)?;
    idx.seal()?;
    let batch_params = SearchParams::new().with_nprobe((nlist / 4).max(1));
    // single-query mode probes every list: the intra-query multi-list
    // fan-out is what lets one big query use the whole socket
    let single_params = SearchParams::new().with_nprobe(nlist);

    let mut table = Table::new(
        &format!(
            "Thread scaling ({dataset} n={n} nq={nq}, IVF{nlist},PQ{m}x{}fs)",
            width.bits()
        ),
        &["threads", "mode", "ms", "QPS", "speedup"],
    );
    let trials = trials.max(1);
    // baseline ms per (mode, executor path): batch/pool, batch/scoped,
    // multi-list/pool, multi-list/scoped
    let mut base_ms = [f64::NAN; 4];
    for (ti, &t) in threads.iter().enumerate() {
        let execs: [(&str, QueryExecutor); 2] =
            [("pool", QueryExecutor::new(t)), ("scoped", QueryExecutor::new_scoped(t))];
        let modes: [(&str, &[f32], &SearchParams, f64); 2] = [
            ("batch", &ds.queries, &batch_params, nq as f64),
            ("multi-list", &ds.queries[..ds.dim], &single_params, 1.0),
        ];
        for (mi, (mode, queries, params, queries_per_call)) in modes.into_iter().enumerate() {
            let req = QueryRequest::top_k(queries, 10).with_params(params.clone());
            for (ei, (path, exec)) in execs.iter().enumerate() {
                idx.query_exec(&req, exec)?; // warm the scratch pool
                let mut best = f64::INFINITY;
                for _ in 0..trials {
                    let timer = Timer::start();
                    let resp = idx.query_exec(&req, exec)?;
                    let ms = timer.elapsed_ms();
                    black_box(resp.hits.len());
                    best = best.min(ms);
                }
                let bi = mi * 2 + ei;
                if ti == 0 {
                    base_ms[bi] = best;
                }
                table.row(vec![
                    t.to_string(),
                    format!("{mode}/{path}"),
                    format!("{best:.3}"),
                    format!("{:.0}", queries_per_call / (best / 1e3)),
                    format!("{:.2}x", base_ms[bi] / best),
                ]);
            }
        }
    }
    Ok(table)
}

/// A numeric bench knob from the environment (`ARMPQ_BENCH_N`-style),
/// falling back to `default` — shared by the bench mains so every
/// harness parses the environment the same way.
pub fn bench_env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The bench harnesses' storage mode from `ARMPQ_BENCH_MMAP` (truthy:
/// `1`/`true`/`yes`) and `ARMPQ_BENCH_BUDGET_MB`: `Some` when a zero-copy
/// mapped reopen was requested (see [`run_table1_with`]), `None` for the
/// default in-heap measurement — so a bench can run against an index
/// larger than RAM without new CLI plumbing.
pub fn bench_open_from_env() -> Option<OpenOptions> {
    let mapped = std::env::var("ARMPQ_BENCH_MMAP")
        .map(|v| matches!(v.trim(), "1" | "true" | "yes"))
        .unwrap_or(false);
    if !mapped {
        return None;
    }
    let budget_mb =
        std::env::var("ARMPQ_BENCH_BUDGET_MB").ok().and_then(|v| v.trim().parse().ok());
    Some(OpenOptions { mmap: true, budget_mb })
}

/// The bench harnesses' thread axis from `ARMPQ_BENCH_THREADS`
/// (comma-separated), falling back to the [`default_thread_axis`] — THE
/// single parser shared by the fig2 harnesses so every bench reads the
/// environment the same way.
pub fn thread_axis_from_env() -> Vec<usize> {
    let explicit: Vec<usize> = std::env::var("ARMPQ_BENCH_THREADS")
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect())
        .unwrap_or_default();
    default_thread_axis(&explicit)
}

/// The `--threads` sweep list for benches: explicit values, or the
/// default `1, 2, 4, ncpu` axis (deduplicated, sorted).
pub fn default_thread_axis(explicit: &[usize]) -> Vec<usize> {
    let mut axis: Vec<usize> = if explicit.is_empty() {
        let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        vec![1, 2, 4, ncpu]
    } else {
        explicit.to_vec()
    };
    axis.sort_unstable();
    axis.dedup();
    axis
}

/// Fig. 1 concept micro-benchmark: cost of one ADC lookup step, per code
/// width (the Quicker-ADC trade-off axis).
///
/// Compares (a) the in-memory f32 table gather (Fig. 1a), (b) the portable
/// dual-lane NEON-emulation shuffle (Fig. 1c as the paper models it), and
/// (c) the real-SIMD shuffle the host offers — per 32-code block, at the
/// given [`CodeWidth`].
pub fn run_kernel_micro(m: usize, width: CodeWidth) -> Table {
    use crate::pq::bitwidth::build_width_luts;
    use crate::pq::fastscan::{accumulate_block_portable, LaneWiring};
    use crate::util::rng::Rng;

    let mut rng = Rng::new(0xF16);
    let cols = width.code_columns(m);
    let sub_ksub = width.sub_ksub();
    let block: Vec<u8> =
        (0..32 * width.chunks(m)).map(|_| (rng.next_u32() & 0xFF) as u8).collect();
    let luts_f32: Vec<f32> = (0..cols * sub_ksub).map(|_| rng.next_f32() * 8.0).collect();
    let wl = build_width_luts(&luts_f32, m, width);
    let kluts = wl.kernel;
    let codes: Vec<u8> =
        (0..32 * cols).map(|_| (rng.next_u32() as usize % sub_ksub) as u8).collect();

    let runner = BenchRunner::default();
    let mut table = Table::new(
        &format!("Fig1 lookup micro (M={m}, {width}, per 32-code block)"),
        &["method", "ns/block", "ns/code", "rel"],
    );

    // (a) memory-lookup baseline: 32 codes × cols f32 gathers
    let mem = runner.bench("memory LUT", || {
        let mut total = 0.0f32;
        for i in 0..32 {
            let c = &codes[i * cols..(i + 1) * cols];
            let mut d = 0.0f32;
            for mi in 0..cols {
                d += luts_f32[mi * sub_ksub + c[mi] as usize];
            }
            total += d;
        }
        black_box(total);
    });

    // (b) portable dual-lane emulation (ARMv8: 2 × 128-bit Q-registers)
    let mut out = [0u16; 32];
    let portable = runner.bench("portable dual-lane", || {
        accumulate_block_portable(&block, &kluts, &mut out);
        black_box(out[0]);
    });

    // (b') ARMv7 model: 4 × 64-bit D-registers + vtbl2 (paper §3 notes
    // ARMv7 only has 64-bit registers — this is that fallback). The model
    // covers the paired wiring (2-/4-bit) only.
    let armv7 = (kluts.wiring == LaneWiring::PairedTables).then(|| {
        runner.bench("portable quad-64bit (ARMv7)", || {
            crate::simd::u8x8::accumulate_block_armv7(&block, &kluts, &mut out);
            black_box(out[0]);
        })
    });

    // (c) real SIMD if available: SSSE3 on x86_64, NEON on aarch64
    let ssse3 = if available_backends().contains(&Backend::Ssse3) {
        #[cfg(target_arch = "x86_64")]
        {
            use crate::pq::fastscan::accumulate_block_ssse3;
            Some(runner.bench("ssse3 dual-lane", || {
                unsafe { accumulate_block_ssse3(&block, &kluts, &mut out) };
                black_box(out[0]);
            }))
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            None
        }
    } else {
        None
    };
    let neon = if available_backends().contains(&Backend::Neon) {
        #[cfg(target_arch = "aarch64")]
        {
            use crate::pq::fastscan::accumulate_block_neon;
            Some(runner.bench("neon dual-lane", || {
                unsafe { accumulate_block_neon(&block, &kluts, &mut out) };
                black_box(out[0]);
            }))
        }
        #[cfg(not(target_arch = "aarch64"))]
        {
            None
        }
    } else {
        None
    };

    let base = mem.ns_per_iter();
    for meas in [Some(mem), armv7, Some(portable), ssse3, neon].into_iter().flatten() {
        table.row(vec![
            meas.name.clone(),
            format!("{:.1}", meas.ns_per_iter()),
            format!("{:.2}", meas.ns_per_iter() / 32.0),
            format!("{:.2}x", base / meas.ns_per_iter()),
        ]);
    }
    table
}

/// Filter-pushdown micro-benchmark: masked reservoir scan vs the naive
/// "scan everything, post-filter the candidates" strategy, swept over the
/// filter-selectivity axis (the `--filter-selectivity` sweep), per
/// backend at one code width.
///
/// Masked scans skip all-filtered blocks and never admit filtered lanes,
/// so at low selectivity they should win outright; at 100% they measure
/// the pure overhead of carrying a mask.
pub fn run_filter_micro(n: usize, m: usize, width: CodeWidth, sel_pcts: &[usize], seed: u64) -> Table {
    use crate::pq::bitwidth::build_width_luts;
    use crate::pq::fastscan::{scan_filtered, scan_into_reservoir, FilterMask, ScanSink};
    use crate::pq::PackedCodes;
    use crate::util::rng::Rng;
    use crate::util::topk::U16Reservoir;

    let mut rng = Rng::new(seed);
    let cols = width.code_columns(m);
    let sub_ksub = width.sub_ksub();
    let codes: Vec<u8> =
        (0..n * cols).map(|_| (rng.next_u32() as usize % sub_ksub) as u8).collect();
    let luts_f32: Vec<f32> = (0..cols * sub_ksub).map(|_| rng.next_f32() * 8.0).collect();
    let wl = build_width_luts(&luts_f32, m, width);
    let packed = PackedCodes::pack(&codes, m, width).unwrap();
    let kluts = wl.kernel;
    let k = 10;

    let runner = BenchRunner::default();
    let mut table = Table::new(
        &format!("Filter pushdown micro (n={n}, M={m}, {width})"),
        &["backend", "selectivity", "masked ms", "postfilter ms", "masked/postfilter"],
    );
    for backend in available_backends() {
        for &pct in sel_pcts {
            // deterministic pseudo-random admission at ~pct%
            let mask = FilterMask::from_fn(n, |pos| {
                (pos.wrapping_mul(2654435761) >> 7) % 100 < pct
            });
            let masked = runner.bench(&format!("masked {backend} {pct}%"), || {
                let mut res = U16Reservoir::new(k, 8);
                let mut sink = ScanSink::TopK(&mut res);
                scan_filtered(&packed, &kluts, backend, None, Some(&mask), &mut sink);
                black_box(res.into_candidates());
            });
            let post = runner.bench(&format!("postfilter {backend} {pct}%"), || {
                // naive strategy: unfiltered scan, then drop candidates the
                // filter rejects (under-filling k — the correctness gap the
                // pushdown removes; here we only measure its *cost*)
                let mut res = U16Reservoir::new(k, 8);
                scan_into_reservoir(&packed, &kluts, backend, None, &mut res);
                let cands: Vec<(u16, i64)> = res
                    .into_candidates()
                    .into_iter()
                    .filter(|&(_, l)| mask.passes(l as usize))
                    .collect();
                black_box(cands);
            });
            table.row(vec![
                backend.to_string(),
                format!("{pct}%"),
                format!("{:.3}", masked.ms_per_iter()),
                format!("{:.3}", post.ms_per_iter()),
                format!("{:.2}x", masked.sec_per_iter / post.sec_per_iter),
            ]);
        }
    }
    table
}

/// Range-query mode of the layout ablation: in-register threshold
/// collection (the `ScanSink::Range` path) vs a flat scalar range scan,
/// per backend at one code width, at a radius admitting ~1% of the codes.
pub fn run_ablation_layout_range(n: usize, m: usize, width: CodeWidth, seed: u64) -> Table {
    use crate::pq::bitwidth::build_width_luts;
    use crate::pq::fastscan::{fastscan_distances_all, scan_filtered, ScanSink};
    use crate::pq::PackedCodes;
    use crate::util::rng::Rng;

    let mut rng = Rng::new(seed);
    let cols = width.code_columns(m);
    let sub_ksub = width.sub_ksub();
    let codes: Vec<u8> =
        (0..n * cols).map(|_| (rng.next_u32() as usize % sub_ksub) as u8).collect();
    let luts_f32: Vec<f32> = (0..cols * sub_ksub).map(|_| rng.next_f32() * 8.0).collect();
    let wl = build_width_luts(&luts_f32, m, width);
    let packed = PackedCodes::pack(&codes, m, width).unwrap();
    let kluts = wl.kernel;

    // bound admitting ~1% of the database (computed once, portable kernel)
    let mut all = fastscan_distances_all(&packed, &kluts, Backend::Portable);
    all.sort_unstable();
    let bound = all[n / 100];

    let runner = BenchRunner::default();
    let mut table = Table::new(
        &format!("Ablation range scan (n={n}, M={m}, {width}, ~1% hit rate)"),
        &["variant", "ms/scan", "codes/s", "rel"],
    );
    let interleaved: Vec<_> = available_backends()
        .into_iter()
        .map(|backend| {
            runner.bench(&format!("range interleaved+{backend}"), || {
                let mut hits: Vec<(u16, i64)> = Vec::new();
                let mut sink = ScanSink::Range { bound, hits: &mut hits };
                scan_filtered(&packed, &kluts, backend, None, None, &mut sink);
                black_box(hits);
            })
        })
        .collect();
    // scalar baseline: full distance pass + compare
    let scalar = runner.bench("range flat+scalar", || {
        let all = fastscan_distances_all(&packed, &kluts, Backend::Portable);
        let hits: Vec<(u16, i64)> = all
            .into_iter()
            .enumerate()
            .filter(|&(_, d)| d <= bound)
            .map(|(i, d)| (d, i as i64))
            .collect();
        black_box(hits);
    });
    let base = scalar.sec_per_iter;
    for meas in std::iter::once(scalar).chain(interleaved) {
        table.row(vec![
            meas.name.clone(),
            format!("{:.3}", meas.ms_per_iter()),
            format!("{:.2e}", n as f64 * meas.per_sec()),
            format!("{:.2}x", base / meas.sec_per_iter),
        ]);
    }
    table
}

/// Ablation: u8 LUT quantization (with/without re-ranking) vs exact f32
/// tables — quantifies the accuracy cost of Eq. 4's approximation.
pub fn run_ablation_lut(dataset: &str, n: usize, nq: usize, m: usize, seed: u64) -> Result<Table> {
    let ds = make_dataset(dataset, n, nq, seed);
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    let mut table = Table::new(
        &format!("Ablation LUT quantization ({dataset}, M={m})"),
        &["variant", "recall@1", "recall@10"],
    );

    // exact f32 scan (naive PQ — upper bound for these codes)
    let mut naive = IndexPq::new(ds.dim, PqParams::new_4bit(m));
    naive.train(&ds.train)?;
    naive.add(&ds.base)?;
    let r = naive.search(&ds.queries, 10, None)?;
    table.row(vec![
        "f32 LUT (exact ADC)".into(),
        format!("{:.3}", recall_at_r(&gt, 1, &r.labels, 10, 1)),
        format!("{:.3}", recall_at_r(&gt, 1, &r.labels, 10, 10)),
    ]);

    let mut fast = IndexPq4FastScan::new(ds.dim, m);
    fast.train(&ds.train)?;
    fast.add(&ds.base)?;
    fast.seal()?;
    for (rerank, label) in [(true, "u8 LUT + rerank"), (false, "u8 LUT, no rerank")] {
        // one sealed index, rerank toggled per request
        let params = crate::index::SearchParams::new().with_rerank(rerank);
        let r = fast.search(&ds.queries, 10, Some(&params))?;
        table.row(vec![
            label.into(),
            format!("{:.3}", recall_at_r(&gt, 1, &r.labels, 10, 1)),
            format!("{:.3}", recall_at_r(&gt, 1, &r.labels, 10, 10)),
        ]);
    }
    Ok(table)
}

/// Ablation: interleaved block layout + SIMD vs flat codes + scalar
/// gather — isolates how much of the speedup is the layout+shuffle combo,
/// at any code width (the `--width` axis of the Quicker-ADC curve).
pub fn run_ablation_layout(n: usize, m: usize, width: CodeWidth, seed: u64) -> Table {
    use crate::pq::bitwidth::build_width_luts;
    use crate::pq::fastscan::fastscan_distances_all;
    use crate::pq::lut::QuantizedLuts;
    use crate::pq::PackedCodes;
    use crate::util::rng::Rng;

    let mut rng = Rng::new(seed);
    let cols = width.code_columns(m);
    let sub_ksub = width.sub_ksub();
    let codes: Vec<u8> =
        (0..n * cols).map(|_| (rng.next_u32() as usize % sub_ksub) as u8).collect();
    let luts_f32: Vec<f32> = (0..cols * sub_ksub).map(|_| rng.next_f32() * 8.0).collect();
    let wl = build_width_luts(&luts_f32, m, width);
    let packed = PackedCodes::pack(&codes, m, width).unwrap();
    let kluts = wl.kernel;

    // flat packing at the native width (no interleave) + u8 tables for the
    // scalar baseline — what a straightforward port would do
    let bits = width.bits();
    let per_byte = 8 / bits;
    let mut flat = vec![0u8; (n * cols).div_ceil(per_byte)];
    for (i, &c) in codes.iter().enumerate() {
        flat[i / per_byte] |= c << (bits * (i % per_byte));
    }
    let flat_luts = QuantizedLuts::from_f32(&luts_f32, cols, sub_ksub);
    let code_mask: u8 = ((1u16 << bits) - 1) as u8;

    let runner = BenchRunner::default();
    let mut table = Table::new(
        &format!("Ablation code layout (n={n}, M={m}, {width})"),
        &["variant", "ms/scan", "codes/s", "rel"],
    );

    // one row per available backend (portable model + the host's real
    // SIMD — SSSE3 on x86_64, NEON on aarch64), all against flat+scalar
    let interleaved: Vec<_> = available_backends()
        .into_iter()
        .map(|backend| {
            runner.bench(&format!("interleaved+{backend}"), || {
                black_box(fastscan_distances_all(&packed, &kluts, backend));
            })
        })
        .collect();
    let flat_scan = runner.bench("flat+scalar", || {
        let mut out = vec![0u16; n];
        for i in 0..n {
            let mut acc = 0u16;
            for mi in 0..cols {
                let idx = i * cols + mi;
                let byte = flat[idx / per_byte];
                let code = (byte >> (bits * (idx % per_byte))) & code_mask;
                acc = acc.saturating_add(flat_luts.row(mi)[code as usize] as u16);
            }
            out[i] = acc;
        }
        black_box(out);
    });
    let base = flat_scan.sec_per_iter;
    for meas in std::iter::once(flat_scan).chain(interleaved) {
        table.row(vec![
            meas.name.clone(),
            format!("{:.3}", meas.ms_per_iter()),
            format!("{:.2e}", n as f64 * meas.per_sec()),
            format!("{:.2}x", base / meas.sec_per_iter),
        ]);
    }
    table
}

/// Three-layer end-to-end: the PJRT search artifact driven from rust,
/// compared against the in-process rust kernel on the same data.
pub fn run_pjrt_e2e(artifacts_dir: &std::path::Path, trials: usize) -> Result<Table> {
    use crate::coordinator::service::{PjrtBackend, SearchBackend};
    use crate::runtime::EngineHandle;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    let engine = Arc::new(EngineHandle::spawn(artifacts_dir.to_path_buf())?);
    let meta = engine
        .manifest
        .find_by("search", &[("d", 64)])
        .ok_or_else(|| crate::Error::Runtime("no search artifact for d=64".into()))?;
    let (q, n, d, m, k) = (
        meta.params["q"],
        meta.params["n"],
        meta.params["d"],
        meta.params["m"],
        meta.params["k"],
    );
    let name = meta.name.clone();
    let mut rng = Rng::new(314);
    let codes: Vec<i32> = (0..n * m).map(|_| (rng.next_u32() % 16) as i32).collect();
    let codebooks: Vec<f32> = (0..m * 16 * (d / m)).map(|_| rng.next_gaussian()).collect();
    let queries: Vec<f32> = (0..q * d).map(|_| rng.next_gaussian()).collect();

    let backend = PjrtBackend::new(engine.clone(), d, codes.clone(), codebooks.clone())?;
    engine.warm(&name)?;

    let mut table = Table::new(
        &format!("PJRT e2e (artifact {name})"),
        &["path", "ms/batch", "queries/s"],
    );
    let runner = BenchRunner { runs: trials, ..Default::default() };

    let pjrt = runner.bench("pjrt artifact", || {
        black_box(backend.search_batch(&queries, k, None).unwrap());
    });

    // rust in-process equivalent on the same codes (quantized, no rerank)
    use crate::pq::fastscan::{fastscan_distances_all, KernelLuts};
    use crate::pq::lut::QuantizedLuts;
    use crate::pq::PackedCodes;
    let codes_u8: Vec<u8> = codes.iter().map(|&c| c as u8).collect();
    let packed = PackedCodes::pack(&codes_u8, m, CodeWidth::W4).unwrap();
    let backend_simd = crate::simd::best_backend();
    let dsub = d / m;
    let rust = runner.bench("rust in-process", || {
        for qi in 0..q {
            let qrow = &queries[qi * d..(qi + 1) * d];
            let mut luts = vec![0.0f32; m * 16];
            for mi in 0..m {
                for kk in 0..16 {
                    let c = &codebooks[(mi * 16 + kk) * dsub..(mi * 16 + kk + 1) * dsub];
                    luts[mi * 16 + kk] = crate::util::l2_sq(&qrow[mi * dsub..(mi + 1) * dsub], c);
                }
            }
            let qluts = QuantizedLuts::from_f32(&luts, m, 16);
            let kluts = KernelLuts::build(&qluts, packed.lut_rows);
            black_box(fastscan_distances_all(&packed, &kluts, backend_simd));
        }
    });

    for meas in [pjrt, rust] {
        table.row(vec![
            meas.name.clone(),
            format!("{:.2}", meas.ms_per_iter()),
            format!("{:.0}", q as f64 * meas.per_sec()),
        ]);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_small_smoke() {
        std::env::set_var("ARMPQ_BENCH_FAST", "1");
        let t = run_fig2("sift", 2000, 10, &[8], 1, 42).unwrap();
        assert_eq!(t.rows.len(), 2);
        // both methods report the same-ish recall (Fig. 2 claim)
        let rec_naive: f64 = t.rows[0][2].parse().unwrap();
        let rec_fast: f64 = t.rows[1][2].parse().unwrap();
        assert!((rec_naive - rec_fast).abs() <= 0.15, "{rec_naive} vs {rec_fast}");
    }

    #[test]
    fn table1_small_smoke() {
        let t = run_table1(3000, 10, 16, 16, &[1, 2], 1, 43).unwrap();
        assert_eq!(t.rows.len(), 2);
        // nprobe=2 recall >= nprobe=1 recall (allow small noise)
        let r1: f64 = t.rows[0][4].parse().unwrap();
        let r2: f64 = t.rows[1][4].parse().unwrap();
        assert!(r2 + 0.1 >= r1, "r1={r1} r2={r2}");
    }

    #[test]
    fn table1_mapped_matches_heap_recall() {
        // same build seed, heap vs zero-copy mapped reopen: the recall
        // column must be bit-identical (only latency may move)
        let heap = run_table1(2500, 8, 9, 16, &[1, 2], 1, 51).unwrap();
        let mapped = run_table1_with(
            2500,
            8,
            9,
            16,
            &[1, 2],
            1,
            51,
            Some(&OpenOptions { mmap: true, budget_mb: Some(1) }),
        )
        .unwrap();
        assert_eq!(heap.rows.len(), mapped.rows.len());
        for (h, m) in heap.rows.iter().zip(&mapped.rows) {
            assert_eq!(h[4], m[4], "recall must not depend on the storage mode");
        }
    }

    #[test]
    fn kernel_micro_runs_all_widths() {
        std::env::set_var("ARMPQ_BENCH_FAST", "1");
        for width in CodeWidth::ALL {
            let t = run_kernel_micro(16, width);
            assert!(t.rows.len() >= 2, "{width}");
            // the ARMv7 model only covers the paired wiring
            let has_armv7 = t.rows.iter().any(|r| r[0].contains("ARMv7"));
            assert_eq!(has_armv7, width != CodeWidth::W8, "{width}");
        }
    }

    #[test]
    fn thread_scaling_smoke() {
        let t = run_thread_scaling("sift", 2_000, 8, 8, 8, CodeWidth::W4, &[1, 2], 1, 48)
            .unwrap();
        // two modes × two executor paths per thread count
        assert_eq!(t.rows.len(), 8);
        let labels = ["batch/pool", "batch/scoped", "multi-list/pool", "multi-list/scoped"];
        assert!(t.rows.iter().all(|r| labels.contains(&r[1].as_str())), "{:?}", t.rows);
        // every (mode, path) pair appears at each thread count
        for l in labels {
            assert_eq!(t.rows.iter().filter(|r| r[1] == l).count(), 2, "{l}");
        }
        // the threads=1 rows are their own baseline
        assert_eq!(t.rows[0][4], "1.00x");
        assert_eq!(t.rows[1][4], "1.00x");
        let axis = default_thread_axis(&[]);
        assert!(axis.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(default_thread_axis(&[4, 1, 4]), vec![1, 4]);
    }

    #[test]
    fn ablation_lut_ordering() {
        let t = run_ablation_lut("sift", 2000, 20, 8, 44).unwrap();
        assert_eq!(t.rows.len(), 3);
        let exact: f64 = t.rows[0][1].parse().unwrap();
        let rerank: f64 = t.rows[1][1].parse().unwrap();
        // re-ranked must track the exact ADC closely
        assert!((exact - rerank).abs() <= 0.1, "exact {exact} rerank {rerank}");
    }

    #[test]
    fn filter_micro_runs() {
        std::env::set_var("ARMPQ_BENCH_FAST", "1");
        let t = run_filter_micro(32 * 40, 8, CodeWidth::W4, &[1, 50, 100], 46);
        // one row per backend × selectivity
        assert_eq!(t.rows.len(), 3 * crate::simd::available_backends().len());
    }

    #[test]
    fn ablation_layout_range_runs_all_widths() {
        std::env::set_var("ARMPQ_BENCH_FAST", "1");
        for width in CodeWidth::ALL {
            let t = run_ablation_layout_range(32 * 50, 8, width, 47);
            assert_eq!(
                t.rows.len(),
                1 + crate::simd::available_backends().len(),
                "{width}"
            );
        }
    }

    #[test]
    fn ablation_layout_runs_all_widths() {
        std::env::set_var("ARMPQ_BENCH_FAST", "1");
        for width in CodeWidth::ALL {
            let t = run_ablation_layout(32 * 50, 8, width, 45);
            // flat+scalar plus one row per available backend
            assert_eq!(
                t.rows.len(),
                1 + crate::simd::available_backends().len(),
                "{width}"
            );
        }
    }
}
