//! Paper Fig. 2b: PQ vs 4-bit PQ on Deep1M(-like), recall@1 vs QPS, M sweep.
use armpq::experiments::run_fig2;

fn main() {
    let n: usize = std::env::var("ARMPQ_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100_000);
    let nq: usize = std::env::var("ARMPQ_BENCH_NQ").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
    // Deep features are 96-D: M ∈ {8, 16, 32, 48} divide 96 (paper sweeps M similarly)
    let t = run_fig2("deep", n, nq, &[8, 16, 32, 48], 5, 20220502).expect("fig2b");
    t.print();
    t.save().expect("save");
}
