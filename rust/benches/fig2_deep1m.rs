//! Paper Fig. 2b: PQ vs 4-bit PQ on Deep1M(-like), recall@1 vs QPS, M sweep.
//! The threads axis (ARMPQ_BENCH_THREADS, default `1,2,4,ncpu`) appends
//! the executor thread-scaling curve on the same dataset.
use armpq::experiments::{bench_env_usize, run_fig2, run_thread_scaling, thread_axis_from_env};
use armpq::pq::CodeWidth;

fn main() {
    let n = bench_env_usize("ARMPQ_BENCH_N", 100_000);
    let nq = bench_env_usize("ARMPQ_BENCH_NQ", 100);
    // Deep features are 96-D: M ∈ {8, 16, 32, 48} divide 96 (paper sweeps M similarly)
    let t = run_fig2("deep", n, nq, &[8, 16, 32, 48], 5, 20220502).expect("fig2b");
    t.print();
    t.save().expect("save");
    let t = run_thread_scaling(
        "deep",
        n,
        nq,
        (n as f64).sqrt() as usize,
        16,
        CodeWidth::W4,
        &thread_axis_from_env(),
        5,
        20220502,
    )
    .expect("fig2b threads");
    t.print();
    t.save().expect("save");
}
