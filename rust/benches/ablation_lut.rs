//! Ablation: u8 LUT quantization (paper Eq. 4) vs exact f32 tables.
use armpq::experiments::run_ablation_lut;

fn main() {
    let n: usize = std::env::var("ARMPQ_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(50_000);
    for (ds, m) in [("sift", 16), ("deep", 16)] {
        let t = run_ablation_lut(ds, n, 100, m, 20220504).expect("ablation");
        t.print();
        t.save().expect("save");
    }
}
