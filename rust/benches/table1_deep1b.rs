//! Paper Table 1: IVF + HNSW + PQ16x4fs on Deep1B (scaled to ARMPQ_BENCH_N,
//! default 200k; nlist = sqrt(N) per the paper's heuristic).
//!
//! `ARMPQ_BENCH_MMAP=1` measures the zero-copy mapped reopen of the built
//! index instead of the in-heap copy (`ARMPQ_BENCH_BUDGET_MB` caps the
//! advised residency) — the configuration for data larger than RAM.
use armpq::experiments::{bench_open_from_env, run_table1_with};

fn main() {
    let n: usize = std::env::var("ARMPQ_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(200_000);
    let nq: usize = std::env::var("ARMPQ_BENCH_NQ").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
    let nlist = (n as f64).sqrt() as usize;
    let open = bench_open_from_env();
    let t = run_table1_with(n, nq, nlist, 16, &[1, 2, 4], 5, 20220503, open.as_ref())
        .expect("table1");
    t.print();
    t.save().expect("save");
    println!("\npaper reference (Deep1B, Graviton2): nprobe 1/2/4 -> recall 0.072/0.082/0.086, 0.51/0.83/1.3 ms/query");
}
