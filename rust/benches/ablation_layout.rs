//! Ablation: interleaved block layout + SIMD vs flat 4-bit codes + scalar
//! gather ("we must carefully maintain the code layout", paper §3).
use armpq::experiments::run_ablation_layout;

fn main() {
    for m in [8, 16, 32] {
        let t = run_ablation_layout(320_000, m, 20220505);
        t.print();
        t.save().expect("save");
    }
}
