//! Ablation: interleaved block layout + SIMD vs flat codes + scalar
//! gather ("we must carefully maintain the code layout", paper §3), at
//! every fastscan code width — the data for the Quicker-ADC trade-off
//! curve (EXPERIMENTS.md) — plus the range-query mode: in-register
//! threshold collection vs a scalar distance pass at ~1% hit rate.
use armpq::experiments::{run_ablation_layout, run_ablation_layout_range};
use armpq::pq::CodeWidth;

fn main() {
    for width in CodeWidth::ALL {
        for m in [8, 16, 32] {
            let t = run_ablation_layout(320_000, m, width, 20220505);
            t.print();
            t.save().expect("save");
        }
        let t = run_ablation_layout_range(320_000, 16, width, 20220728);
        t.print();
        t.save().expect("save");
    }
}
