//! Paper Fig. 2a: PQ vs 4-bit PQ on SIFT1M(-like), recall@1 vs QPS, M sweep.
//! Scale with ARMPQ_BENCH_N (default 100k; paper used 1M). The threads
//! axis (ARMPQ_BENCH_THREADS, default `1,2,4,ncpu`) appends the executor
//! thread-scaling curve on the same dataset.
use armpq::experiments::{bench_env_usize, run_fig2, run_thread_scaling, thread_axis_from_env};
use armpq::pq::CodeWidth;

fn main() {
    let n = bench_env_usize("ARMPQ_BENCH_N", 100_000);
    let nq = bench_env_usize("ARMPQ_BENCH_NQ", 100);
    let t = run_fig2("sift", n, nq, &[8, 16, 32, 64], 5, 20220501).expect("fig2a");
    t.print();
    t.save().expect("save");
    let t = run_thread_scaling(
        "sift",
        n,
        nq,
        (n as f64).sqrt() as usize,
        16,
        CodeWidth::W4,
        &thread_axis_from_env(),
        5,
        20220501,
    )
    .expect("fig2a threads");
    t.print();
    t.save().expect("save");
}
