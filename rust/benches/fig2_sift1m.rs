//! Paper Fig. 2a: PQ vs 4-bit PQ on SIFT1M(-like), recall@1 vs QPS, M sweep.
//! Scale with ARMPQ_BENCH_N (default 100k; paper used 1M).
use armpq::experiments::run_fig2;

fn main() {
    let n: usize = std::env::var("ARMPQ_BENCH_N").ok().and_then(|v| v.parse().ok()).unwrap_or(100_000);
    let nq: usize = std::env::var("ARMPQ_BENCH_NQ").ok().and_then(|v| v.parse().ok()).unwrap_or(100);
    let t = run_fig2("sift", n, nq, &[8, 16, 32, 64], 5, 20220501).expect("fig2a");
    t.print();
    t.save().expect("save");
}
