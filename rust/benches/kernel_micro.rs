//! Paper Fig. 1 concept: per-lookup-op cost — memory LUT vs dual-lane
//! shuffle (portable NEON model) vs real SIMD — per 32-code block, swept
//! over the Quicker-ADC width axis (2-/4-/8-bit codes), plus the
//! filter-pushdown sweep: masked scan vs scan-then-post-filter at
//! 1/10/50/100% selectivity (`--filter-selectivity 1,10,50,100` and
//! `--filter-n` to override), plus the executor thread-scaling curve
//! (`--threads 1,2,4` — default `1, 2, 4, ncpu`): batch fan-out and
//! single-query multi-list fan-out per width.
use armpq::experiments::{
    default_thread_axis, run_filter_micro, run_kernel_micro, run_thread_scaling,
};
use armpq::pq::CodeWidth;
use armpq::util::args::Args;

fn main() {
    let args = Args::from_env();
    let sels = args.get_usize_list("filter-selectivity", &[1, 10, 50, 100]);
    let filter_n = args.get_usize("filter-n", 320_000);
    let threads = default_thread_axis(&args.get_usize_list("threads", &[]));
    let scale_n = args.get_usize("scale-n", 100_000);
    for width in CodeWidth::ALL {
        for m in [8, 16, 32, 64] {
            let t = run_kernel_micro(m, width);
            t.print();
            t.save().expect("save");
        }
        let t = run_filter_micro(filter_n, 16, width, &sels, 20220728);
        t.print();
        t.save().expect("save");
        let t = run_thread_scaling("sift", scale_n, 64, 64, 16, width, &threads, 3, 20260728)
            .expect("thread scaling");
        t.print();
        t.save().expect("save");
    }
}
