//! Paper Fig. 1 concept: per-lookup-op cost — memory LUT vs dual-lane
//! shuffle (portable NEON model) vs real SIMD — per 32-code block, swept
//! over the Quicker-ADC width axis (2-/4-/8-bit codes).
use armpq::experiments::run_kernel_micro;
use armpq::pq::CodeWidth;

fn main() {
    for width in CodeWidth::ALL {
        for m in [8, 16, 32, 64] {
            let t = run_kernel_micro(m, width);
            t.print();
            t.save().expect("save");
        }
    }
}
