//! Paper Fig. 1 concept: per-lookup-op cost — memory LUT vs dual-lane
//! shuffle (portable NEON model) vs real SIMD (SSSE3), per 32-code block.
use armpq::experiments::run_kernel_micro;

fn main() {
    for m in [8, 16, 32, 64] {
        let t = run_kernel_micro(m);
        t.print();
        t.save().expect("save");
    }
}
