//! Three-layer end-to-end: PJRT search artifact (L1 pallas + L2 jax, AOT)
//! driven from rust vs the in-process rust kernel. Needs `make artifacts`.
use armpq::experiments::run_pjrt_e2e;

fn main() {
    match run_pjrt_e2e(std::path::Path::new("artifacts"), 5) {
        Ok(t) => {
            t.print();
            t.save().expect("save");
        }
        Err(e) => {
            eprintln!("skipped: {e} (run `make artifacts`)");
        }
    }
}
