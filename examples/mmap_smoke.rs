//! Zero-copy smoke test: prove a v3 mmap open touches O(metadata) bytes,
//! not the whole file, by opening a code region much larger than the
//! process is allowed to allocate.
//!
//! ```bash
//! cargo run --release --example mmap_smoke -- --n 4000000 --budget-mb 4
//! ```
//!
//! The harness builds a flat fastscan index whose packed code region is
//! tens of MiB, saves it in format v3, frees every build buffer, then
//! clamps `RLIMIT_DATA` far below the file size (Linux ≥ 4.7 counts
//! private anonymous memory against it — file-backed `MAP_SHARED` pages
//! are exempt). A regression that sneaks a heap read back into the
//! mapped open path would abort on the allocation; the honest zero-copy
//! open sails through, and the `VmRSS` delta across the open stays a
//! small fraction of the file. Prints `PASS` on success; exits non-zero
//! otherwise.

use armpq::index::io::{load_pq4fs_with, save_pq4fs};
use armpq::index::{Index, IndexPq4FastScan, QueryRequest};
use armpq::pq::{CodeWidth, PqParams, ProductQuantizer};
use armpq::storage::OpenOptions;
use armpq::util::args::Args;
use armpq::util::rng::Rng;
use armpq::util::timer::Timer;

#[cfg(target_os = "linux")]
mod rlim {
    #[repr(C)]
    pub struct Rlimit {
        pub cur: u64,
        pub max: u64,
    }
    extern "C" {
        pub fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    pub const RLIMIT_DATA: i32 = 2;
}

/// Resident set size from /proc (None off Linux — the check degrades).
fn vm_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmRSS:"))?;
    line.split_whitespace().nth(1)?.parse().ok()
}

fn main() -> armpq::Result<()> {
    // stay serial unless told otherwise: worker-thread stacks are private
    // anonymous mappings and would count against the RLIMIT_DATA cap below
    if std::env::var("ARMPQ_THREADS").is_err() {
        std::env::set_var("ARMPQ_THREADS", "1");
    }
    let args = Args::from_env();
    let n = args.get_usize("n", 4_000_000);
    let m = args.get_usize("m", 16);
    let budget_mb = args.get_u64("budget-mb", 4);
    let dim = 2 * m; // dsub = 2: tiny codebook, the codes dominate
    let width = CodeWidth::W4;

    // 1. train a small codebook, then synthesize codes directly — the
    //    point is a big packed region, not a realistic dataset
    let mut rng = Rng::new(42);
    let train: Vec<f32> = (0..2_000 * dim).map(|_| rng.next_gaussian()).collect();
    let pq = ProductQuantizer::train(&train, dim, &PqParams::new_4bit(m))?;
    let mut codes = vec![0u8; n * m];
    for c in codes.iter_mut() {
        *c = (rng.next_u32() % 16) as u8;
    }
    let index = IndexPq4FastScan::from_parts_width(pq, codes, width)?;

    let dir = std::env::temp_dir().join(format!("armpq_mmap_smoke_{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("smoke.idx");
    save_pq4fs(&index, &path)?;
    drop(index); // free every build buffer before the limit drops
    let file_mb = std::fs::metadata(&path)?.len() / (1 << 20);
    println!("saved {} ({} MiB packed-region file)", path.display(), file_mb);

    // 2. clamp anonymous memory far below the file size — from here on a
    //    whole-file heap read aborts, a zero-copy map does not
    #[cfg(target_os = "linux")]
    {
        let limit_mb = (file_mb / 2).clamp(16, 256);
        let r = rlim::Rlimit { cur: limit_mb << 20, max: limit_mb << 20 };
        let rc = unsafe { rlim::setrlimit(rlim::RLIMIT_DATA, &r) };
        println!("RLIMIT_DATA := {limit_mb} MiB (rc={rc})");
    }
    #[cfg(not(target_os = "linux"))]
    println!("(no RLIMIT_DATA on this target; relying on the VmRSS check)");

    // 3. the mapped open itself: O(metadata) work, O(budget) residency
    let rss_before = vm_rss_kb();
    let t = Timer::start();
    let opened = load_pq4fs_with(
        &path,
        &OpenOptions { mmap: true, budget_mb: Some(budget_mb) },
    )?;
    let open_ms = t.elapsed_ms();
    let rss_after = vm_rss_kb();
    let packed = opened.packed().expect("mapped open must adopt the packed block");
    assert!(packed.data.is_mapped(), "open did not map the code region");
    assert_eq!(packed.data[..].as_ptr() as usize % 64, 0, "code region lost its alignment");
    println!(
        "mapped open: {open_ms:.1} ms, {} MiB mapped, budget {budget_mb} MiB",
        packed.mapped_bytes() >> 20
    );
    if let (Some(b), Some(a)) = (rss_before, rss_after) {
        let delta_mb = a.saturating_sub(b) / 1024;
        println!("VmRSS across open: {b} KiB -> {a} KiB (+{delta_mb} MiB)");
        assert!(
            delta_mb <= (file_mb / 4).max(budget_mb + 8),
            "open resident growth {delta_mb} MiB looks like a full-file read of {file_mb} MiB"
        );
    }

    // 4. queries stream pages in on demand and stay well-formed
    let queries: Vec<f32> = (0..4 * dim).map(|_| rng.next_gaussian()).collect();
    let t = Timer::start();
    let resp = opened.query(&QueryRequest::top_k(&queries, 10))?;
    println!(
        "4 queries in {:.1} ms; stats: bytes_mapped={} codes_scanned={}",
        t.elapsed_ms(),
        resp.stats[0].bytes_mapped,
        resp.stats[0].codes_scanned
    );
    assert_eq!(resp.nq(), 4);
    assert!(resp.hits.iter().all(|row| row.len() == 10));
    assert!(resp.stats.iter().all(|s| s.bytes_mapped > 0));

    drop(opened);
    std::fs::remove_dir_all(&dir).ok();
    println!("PASS");
    Ok(())
}
