//! Three-layer pipeline demo: the rust coordinator (L3) drives the
//! AOT-compiled JAX model (L2) containing the Pallas fastscan kernel (L1)
//! through PJRT — python nowhere at runtime.
//!
//! Requires `make artifacts` first.
//!
//! ```bash
//! cargo run --release --example pjrt_pipeline
//! ```

use armpq::coordinator::service::{PjrtBackend, SearchBackend};
use armpq::pq::{PqParams, ProductQuantizer};
use armpq::runtime::EngineHandle;
use armpq::util::rng::Rng;
use armpq::util::timer::Timer;
use std::sync::Arc;

fn main() -> armpq::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    let engine = Arc::new(EngineHandle::spawn(dir)?);
    println!("engine up; artifacts:");
    for a in &engine.manifest.artifacts {
        println!("  {:32} {:?}", a.name, a.params);
    }

    // pick the d=64 search artifact
    let meta = engine
        .manifest
        .find_by("search", &[("d", 64)])
        .ok_or_else(|| armpq::Error::Runtime("need search artifact for d=64 (make artifacts)".into()))?
        .clone();
    let (n, d, m, k) = (meta.params["n"], meta.params["d"], meta.params["m"], meta.params["k"]);

    // Train a real PQ on synthetic data, encode N vectors — same path the
    // rust-only index uses — then hand codes+codebooks to the PJRT backend.
    let mut rng = Rng::new(99);
    let ntrain = 4000;
    let train: Vec<f32> = (0..ntrain * d).map(|_| rng.next_gaussian()).collect();
    let pq = ProductQuantizer::train(&train, d, &PqParams::new_4bit(m))?;
    let base: Vec<f32> = (0..n * d).map(|_| rng.next_gaussian()).collect();
    let codes_u8 = pq.encode(&base)?;
    let codes: Vec<i32> = codes_u8.iter().map(|&c| c as i32).collect();
    println!("encoded {n} vectors with PQ{m}x4 (codebooks from rust k-means)");

    let backend = PjrtBackend::new(engine.clone(), d, codes, pq.centroids.clone())?;
    println!("backend: {}", backend.describe());

    // warm (compile) then run a few batches
    let queries: Vec<f32> = (0..32 * d).map(|_| rng.next_gaussian()).collect();
    let t = Timer::start();
    let (dists, labels) = backend.search_batch(&queries, k, None)?;
    println!("first batch (incl. XLA compile): {:.1} ms", t.elapsed_ms());

    let t = Timer::start();
    let iters = 20;
    for _ in 0..iters {
        let _ = backend.search_batch(&queries, k, None)?;
    }
    let ms = t.elapsed_ms() / iters as f64;
    println!(
        "steady state: {:.2} ms per 32-query batch → {:.0} queries/s through PJRT",
        ms,
        32.0 * 1e3 / ms
    );

    // sanity: results are valid and self-consistent with the rust kernel
    assert_eq!(labels.len(), 32 * k);
    assert!(labels.iter().all(|&l| l >= 0 && (l as usize) < n));
    for qi in 0..32 {
        let row = &dists[qi * k..(qi + 1) * k];
        assert!(row.windows(2).all(|w| w[0] <= w[1]), "unsorted row {qi}");
    }
    println!("query 0 top-3: {:?} @ {:?}", &labels[..3], &dists[..3]);
    println!("pjrt_pipeline OK — L3 (rust) → L2 (jax) → L1 (pallas) verified");
    Ok(())
}
