//! Quickstart: train a 4-bit fastscan index, search it, check recall.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use armpq::datasets::SyntheticDataset;
use armpq::eval::{ground_truth, recall_at_r};
use armpq::index::{index_factory, Index};
use armpq::util::timer::Timer;

fn main() -> armpq::Result<()> {
    // 1. A SIFT-like dataset (synthetic stand-in for SIFT1M; see DESIGN.md).
    let ds = SyntheticDataset::sift_like(50_000, 100, 42);
    println!("dataset: n={} nq={} dim={}", ds.n(), ds.nq(), ds.dim);

    // 2. The paper's index: 4-bit PQ (M=16, K=16) with the SIMD fastscan
    //    kernel. The factory string mirrors faiss ("PQ16x4fs").
    let mut index = index_factory(ds.dim, "PQ16x4fs")?;
    let t = Timer::start();
    index.train(&ds.train)?;
    index.add(&ds.base)?;
    index.seal()?; // build phase done: the index is now immutable to search
    println!("built {} in {:.1}s", index.describe(), t.elapsed_s());

    // 3. Search all queries (read-only — shareable across threads).
    let t = Timer::start();
    let result = index.search(&ds.queries, 10, None)?;
    let ms = t.elapsed_ms() / ds.nq() as f64;
    println!("search: {:.3} ms/query ({:.0} QPS single-thread)", ms, 1e3 / ms);

    // 4. Accuracy against exact ground truth.
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);
    println!(
        "recall@1 = {:.3}, recall@10 = {:.3}",
        recall_at_r(&gt, 1, &result.labels, 10, 1),
        recall_at_r(&gt, 1, &result.labels, 10, 10),
    );

    // 5. Compare against the naive-PQ baseline on the same codes.
    let mut naive = index_factory(ds.dim, "PQ16x4")?;
    naive.train(&ds.train)?;
    naive.add(&ds.base)?;
    naive.seal()?;
    let t = Timer::start();
    let rn = naive.search(&ds.queries, 10, None)?;
    let ms_naive = t.elapsed_ms() / ds.nq() as f64;
    println!(
        "baseline PQ16x4 (naive scan): {:.3} ms/query — fastscan speedup {:.1}x at recall {:.3}",
        ms_naive,
        ms_naive / ms,
        recall_at_r(&gt, 1, &rn.labels, 10, 1),
    );
    Ok(())
}
