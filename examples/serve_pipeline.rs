//! End-to-end serving driver: build the Table-1 index, start the batching
//! coordinator, fire concurrent clients over TCP, report latency/QPS and
//! recall. This is the repo's full-system validation run (EXPERIMENTS.md
//! §End-to-end).
//!
//! ```bash
//! cargo run --release --example serve_pipeline -- --n 100000 --clients 4
//! ```

use armpq::coordinator::{Client, IvfBackend, Server, ServerConfig};
use armpq::datasets::SyntheticDataset;
use armpq::eval::{ground_truth, recall_at_r};
use armpq::ivf::{IvfParams, IvfPq4};
use armpq::pq::PqParams;
use armpq::util::args::Args;
use armpq::util::timer::{LatencyStats, Timer};
use std::sync::Arc;

fn main() -> armpq::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 100_000);
    let nq_per_client = args.get_usize("nq", 200);
    let clients = args.get_usize("clients", 4);
    let k = args.get_usize("k", 10);
    let nlist = (n as f64).sqrt() as usize;

    // --- build the index (paper §5.2 configuration) ---
    println!("building IVF{nlist}_HNSW32,PQ16x4fs over {n} deep-like vectors…");
    let ds = SyntheticDataset::deep_like(n, clients * nq_per_client, 7);
    let mut params = IvfParams::new(nlist);
    params.coarse_hnsw = true;
    let mut idx = IvfPq4::new(ds.dim, params, PqParams::new_4bit(16));
    let t = Timer::start();
    idx.train(&ds.train)?;
    idx.add(&ds.base)?;
    idx.nprobe = 4;
    println!("index ready in {:.1}s", t.elapsed_s());

    // --- serve ---
    let backend = Arc::new(IvfBackend::new(idx)?);
    let server = Server::start(backend, ServerConfig::default())?;
    let addr = server.addr;
    println!("coordinator listening on {addr}");

    // --- concurrent clients ---
    let dim = ds.dim;
    let queries = Arc::new(ds.queries.clone());
    let t_total = Timer::start();
    let mut handles = Vec::new();
    for c in 0..clients {
        let queries = queries.clone();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::connect(&addr).expect("connect");
            let mut stats = LatencyStats::new();
            let mut labels = Vec::new();
            for i in 0..nq_per_client {
                let qi = c * nq_per_client + i;
                let t = Timer::start();
                let (_d, l, _batch) =
                    client.search(&queries[qi * dim..(qi + 1) * dim], k).expect("search");
                stats.record_ms(t.elapsed_ms());
                labels.extend(l);
            }
            (stats, labels)
        }));
    }
    let mut all_labels = vec![Vec::new(); clients];
    let mut merged = LatencyStats::new();
    for (c, h) in handles.into_iter().enumerate() {
        let (stats, labels) = h.join().expect("client thread");
        for p in [50.0, 95.0] {
            let _ = p;
        }
        for i in 0..stats.count() {
            let _ = i;
        }
        merged.record_ms(stats.mean_ms());
        all_labels[c] = labels;
        println!(
            "client {c}: mean {:.2} ms  p50 {:.2}  p95 {:.2}",
            stats.mean_ms(),
            stats.percentile_ms(50.0),
            stats.percentile_ms(95.0)
        );
    }
    let total_q = clients * nq_per_client;
    let wall = t_total.elapsed_s();
    println!("aggregate: {total_q} queries in {wall:.1}s → {:.0} QPS", total_q as f64 / wall);

    // --- recall against exact ground truth ---
    let gt = ground_truth(&ds.base, &ds.queries, dim, 1);
    let flat: Vec<i64> = all_labels.into_iter().flatten().collect();
    println!("recall@1 = {:.3}  recall@{k} = {:.3}",
        recall_at_r(&gt, 1, &flat, k, 1),
        recall_at_r(&gt, 1, &flat, k, k));

    println!("server metrics: {}", server.metrics_json().to_pretty());
    server.stop();
    Ok(())
}
