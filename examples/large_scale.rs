//! Large-scale search (paper §5.2 / Table 1): IVF + HNSW coarse
//! quantization + 4-bit PQ distance estimation on a Deep1B-like dataset.
//!
//! ```bash
//! cargo run --release --example large_scale -- --n 1000000 --nprobe 1,2,4
//! ```

use armpq::datasets::SyntheticDataset;
use armpq::eval::{ground_truth, measure_search};
use armpq::index::{Index, IndexIvfPq4, SearchParams};
use armpq::util::args::Args;
use armpq::util::timer::Timer;

fn main() -> armpq::Result<()> {
    let args = Args::from_env();
    let n = args.get_usize("n", 200_000);
    let nq = args.get_usize("nq", 100);
    let nprobes = args.get_usize_list("nprobe", &[1, 2, 4]);
    let m = args.get_usize("m", 16);
    // paper heuristic: nlist = sqrt(N) (30 000 for 1B)
    let nlist = args.get_usize("nlist", (n as f64).sqrt() as usize);

    println!("Deep1B-scaled workload: n={n}, nlist={nlist}, M={m}, K=16 (64-bit codes at M=16)");
    let ds = SyntheticDataset::deep_like(n, nq, 2022);

    let mut index = IndexIvfPq4::new(ds.dim, nlist, m, /*hnsw*/ true, 32);
    let t = Timer::start();
    index.train(&ds.train)?;
    println!("trained coarse({nlist}) + PQ in {:.1}s", t.elapsed_s());
    let t = Timer::start();
    index.add(&ds.base)?;
    index.seal()?;
    println!("encoded+packed {} vectors in {:.1}s", index.ntotal(), t.elapsed_s());
    let (lmin, lmean, lmax) = index.inner().list_stats();
    println!(
        "lists: min={lmin} mean={lmean:.0} max={lmax}; code memory {:.1} bits/vector",
        index.inner().code_bits_per_vector()
    );

    println!("computing exact ground truth for {nq} queries…");
    let gt = ground_truth(&ds.base, &ds.queries, ds.dim, 1);

    println!("\n nlist  nprobe   M   K   Recall@1   Runtime(ms/query)");
    for nprobe in nprobes {
        // nprobe travels with each request; the sealed index never changes
        let params = SearchParams::new().with_nprobe(nprobe);
        let meas = measure_search(&ds.queries, ds.dim, &gt, 1, 10, 3, |q, k| {
            let r = index.search(q, k, Some(&params)).unwrap();
            (r.distances, r.labels)
        });
        println!(
            "{:6} {:7} {:3}  16      {:.3}            {:.2}",
            nlist, nprobe, m, meas.recall_at_1, meas.ms_per_query
        );
    }
    println!("\n(cf. paper Table 1: nprobe 1/2/4 → 0.072/0.082/0.086 recall, 0.51/0.83/1.3 ms)");
    Ok(())
}
